"""Simulated multicore (paper §2): p cores, private LRU caches of M words
with block size B, invalidation-based coherence, work-stealing execution of
BP/HBP programs.

The machine *counts* what the paper *bounds*:
  * cache misses (cold/capacity),
  * block misses (coherence invalidations — false sharing, Def. 2.2),
  * steals (per priority level — Obs. 4.3),
  * idle time and total virtual time.

Execution model: discrete-event, one heap event per core step.  Each step
executes one node phase (down-pass head + fork, leaf body, or up-pass join),
whose cost is the sum of its access costs (hit=1, any miss=b).  Work stealing
follows the plugged-in scheduler (PWS or RWS).  Execution stacks follow
§3.3: a stolen task's kernel allocates a fresh block-aligned stack; node
frames are pushed at the down-pass and the up-pass reads child frames —
space reuse across frames is what generates stack block misses, and padding
(Def. 3.3) spaces them out.
"""
from __future__ import annotations

import heapq
import math
from collections import OrderedDict, defaultdict, deque
from dataclasses import dataclass, field
from typing import Optional

from repro.core.hbp import BPProgram, Memory, Node


class LRUCache:
    def __init__(self, n_blocks: int):
        self.capacity = max(n_blocks, 1)
        self.blocks: OrderedDict[int, bool] = OrderedDict()  # block -> dirty?

    def has(self, block: int) -> bool:
        return block in self.blocks

    def touch(self, block: int):
        self.blocks.move_to_end(block)

    def insert(self, block: int) -> Optional[int]:
        """Insert; returns evicted block or None."""
        self.blocks[block] = True
        self.blocks.move_to_end(block)
        if len(self.blocks) > self.capacity:
            evicted, _ = self.blocks.popitem(last=False)
            return evicted
        return None

    def invalidate(self, block: int):
        self.blocks.pop(block, None)


@dataclass
class Stats:
    cache_misses: list[int]
    block_misses: list[int]
    steals: list[tuple[float, int, int, int]] = field(default_factory=list)
    # (time, priority, thief, victim)
    steal_attempts: int = 0
    idle_time: float = 0.0
    finish_time: float = 0.0
    accesses: int = 0
    usurpations: int = 0

    def total_cache_misses(self) -> int:
        return sum(self.cache_misses)

    def total_block_misses(self) -> int:
        return sum(self.block_misses)

    def steals_per_priority(self) -> dict[int, int]:
        out: dict[int, int] = defaultdict(int)
        for _, pr, _, _ in self.steals:
            out[pr] += 1
        return dict(out)


class Machine:
    def __init__(self, p: int, M: int, B: int, *, miss_penalty: int = 4,
                 scheduler=None, padded: bool = False):
        self.p = p
        self.M = M
        self.B = B
        self.b = miss_penalty
        self.scheduler = scheduler
        self.padded = padded

        self.caches = [LRUCache(M // B) for _ in range(p)]
        self.holders: dict[int, set[int]] = defaultdict(set)  # block -> cores
        self.invalidated: list[set[int]] = [set() for _ in range(p)]
        self.stats = Stats([0] * p, [0] * p)

        # per-core state
        self.deques: list[deque] = [deque() for _ in range(p)]  # of Node
        self.current: list[Optional[tuple[Node, str, Node]]] = [None] * p
        # (node, phase "down"|"up", kernel_root)
        self.idle_since: list[Optional[float]] = [None] * p

        # execution stacks: stack_id -> [base, sp]
        self.stack_mem_top = 1 << 40  # stacks live far from global arrays
        self.stacks: list[list[int]] = []
        self.core_stack: list[int] = [-1] * p

        self.events: list[tuple[float, int, int]] = []  # (time, seq, core)
        self._seq = 0

    # -- memory ----------------------------------------------------------------
    def access(self, core: int, addr: int, is_write: bool) -> float:
        self.stats.accesses += 1
        block = addr // self.B
        cache = self.caches[core]
        cost = 1.0
        if cache.has(block):
            cache.touch(block)
        else:
            if block in self.invalidated[core]:
                self.stats.block_misses[core] += 1
                self.invalidated[core].discard(block)
            else:
                self.stats.cache_misses[core] += 1
            cost = float(self.b)
            evicted = cache.insert(block)
            self.holders[block].add(core)
            if evicted is not None:
                self.holders[evicted].discard(core)
        if is_write:
            for other in list(self.holders[block]):
                if other != core:
                    self.caches[other].invalidate(block)
                    self.holders[block].discard(other)
                    self.invalidated[other].add(block)
        return cost

    def _access_all(self, core: int, accesses) -> float:
        t = 0.0
        for addr, w in accesses:
            t += self.access(core, addr, w)
        return t

    # -- stacks (paper §3.3) ------------------------------------------------------
    def new_stack(self) -> int:
        base = self.stack_mem_top
        self.stack_mem_top += 1 << 20  # block-aligned, disjoint
        self.stacks.append([base, base])
        return len(self.stacks) - 1

    def push_frame(self, stack_id: int, words: int) -> int:
        base, sp = self.stacks[stack_id]
        addr = sp
        self.stacks[stack_id][1] = sp + words
        return addr

    def pop_frame(self, stack_id: int, addr: int, words: int):
        # LIFO pop when possible (delayed pops under usurpation are benign
        # for the counting experiments)
        base, sp = self.stacks[stack_id]
        if addr + words == sp:
            self.stacks[stack_id][1] = addr

    # -- execution ---------------------------------------------------------------
    def run_sequence(self, programs, *, max_steps: int = 50_000_000) -> Stats:
        """Run an HBP sequence (Def. 3.4 case 4): components one after
        another; caches persist, stats accumulate, and priorities are offset
        per component so they never recur (Obs. 4.3 accounting)."""
        offset = 0
        for prog in programs:
            prog.priority_offset = offset
            offset += int(math.ceil(math.log2(max(prog.n, 2)))) + 2
            self.run(prog, max_steps=max_steps)
        return self.stats

    def run(self, prog: BPProgram, *, max_steps: int = 50_000_000) -> Stats:
        self.prog = prog
        sched = self.scheduler
        sched.reset(self)

        # core 0 begins the root kernel
        sid = self.new_stack()
        self.core_stack[0] = sid
        self.current[0] = (prog.root, "down", prog.root)
        self._push_event(0.0, 0)
        for c in range(1, self.p):
            self.idle_since[c] = 0.0
            sched.on_idle(self, c, 0.0)

        steps = 0
        while self.events and steps < max_steps:
            t, _, core = heapq.heappop(self.events)
            steps += 1
            if self.current[core] is not None:
                dt = self._step(core, t)
                if self.current[core] is not None:
                    self._push_event(t + dt, core)
                else:
                    nxt = self._take_own(core)
                    if nxt is not None:
                        self.current[core] = (nxt, "down", nxt)
                        self._push_event(t + dt, core)
                    else:
                        self.idle_since[core] = t + dt
                        sched.on_idle(self, core, t + dt)
                self.stats.finish_time = max(self.stats.finish_time, t + dt)
            # round boundary (paper §4.1): steal matching happens only after
            # every core's activity at this timestamp has been processed, so
            # forks made "now" are visible to the round's priority scan
            if not self.events or self.events[0][0] > t:
                sched.flush(self, t)
        return self.stats

    def _push_event(self, t: float, core: int):
        self._seq += 1
        heapq.heappush(self.events, (t, self._seq, core))

    def _take_own(self, core: int) -> Optional[Node]:
        if self.deques[core]:
            return self.deques[core].pop()  # bottom
        return None

    def assign_stolen(self, core: int, node: Node, t: float):
        """Scheduler calls this when a steal completes."""
        sid = self.new_stack()
        self.core_stack[core] = sid
        self.current[core] = (node, "down", node)
        if self.idle_since[core] is not None:
            self.stats.idle_time += t - self.idle_since[core]
            self.idle_since[core] = None
        self._push_event(t, core)

    def steal_from(self, victim: int) -> Optional[Node]:
        if self.deques[victim]:
            return self.deques[victim].popleft()  # head (top)
        return None

    def head_priority(self, victim: int) -> Optional[int]:
        if self.deques[victim]:
            return self.prog.priority(self.deques[victim][0])
        return None

    def _step(self, core: int, t: float) -> float:
        prog = self.prog
        node, phase, kernel_root = self.current[core]
        dt = 0.0
        if phase == "down":
            # allocate frame on this core's current stack
            words = prog.frame_words + (prog.pad_words(node) if self.padded else 0)
            node.frame_addr = self.push_frame(self.core_stack[core], words)
            node.stack_id = self.core_stack[core]
            dt += self._access_all(core, [(node.frame_addr, True),
                                          (node.frame_addr + 1, True)])
            dt += self._access_all(core, prog.head_accesses(node))
            seq = getattr(node, "seq_children", None)
            if seq is not None:
                node.seq_index = 0  # type: ignore[attr-defined]
                self.current[core] = (seq[0], "down", kernel_root)
            elif node.is_leaf:
                dt += self._access_all(core, prog.leaf_accesses(node))
                self.current[core] = (node, "up", kernel_root)
            else:
                self.deques[core].append(node.right)  # bottom
                self.scheduler.on_task_available(self, core, t)
                self.current[core] = (node.left, "down", kernel_root)
        else:  # up
            parent = node.parent
            if parent is None:
                self.current[core] = None  # whole program complete
            elif getattr(parent, "seq_children", None) is not None:
                # HBP sequencing: advance to the next component in order
                parent.seq_index += 1  # type: ignore[attr-defined]
                seq = parent.seq_children  # type: ignore[attr-defined]
                if parent.seq_index < len(seq):
                    self.current[core] = (seq[parent.seq_index], "down", kernel_root)
                else:
                    dt += self._access_all(core, prog.up_accesses(parent))
                    self.current[core] = (parent, "up", kernel_root)
            else:
                parent.join_count += 1
                if parent.join_count == 2:
                    # the later finisher continues up — a usurpation when the
                    # parent frame lives on another kernel's stack (Def. 4.1)
                    dt += self._access_all(
                        core,
                        [(parent.left.frame_addr, False),
                         (parent.right.frame_addr, False),
                         (parent.frame_addr, True)],
                    )
                    dt += self._access_all(core, prog.up_accesses(parent))
                    if parent.stack_id != self.core_stack[core]:
                        self.stats.usurpations += 1
                    self.current[core] = (parent, "up", kernel_root)
                else:
                    self.current[core] = None  # suspend this path
        return max(dt, 1.0)
