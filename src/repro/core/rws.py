"""Randomized Work Stealing (the baseline the paper compares against —
Blumofe & Leiserson [7], analyzed with false sharing in the companion
paper [13]).

An idle core picks a victim uniformly at random and steals the head (top =
largest) task of its deque; on failure it retries after one time unit.
"""
from __future__ import annotations

import math
import random
from typing import Optional


def two_choice(rng: random.Random, ids, load) -> int:
    """Seeded randomized two-choice placement (the d=2 power-of-two-choices
    refinement of the companion paper's uniform victim pick): sample two
    DISTINCT ids uniformly, return the lighter-loaded one, ties to the
    lower id.  The fleet router's randomized arm runs every placement
    through this — the randomness perturbs *where* a request lands, never
    its tokens, mirroring the simulator's wall-time-only nondeterminism.
    With a single candidate there is nothing to choose between."""
    ids = list(ids)
    if len(ids) == 1:
        return ids[0]
    i = rng.randrange(len(ids))
    j = rng.randrange(len(ids) - 1)
    if j >= i:
        j += 1
    a, b = ids[i], ids[j]
    return a if (load[a], a) <= (load[b], b) else b


class RWS:
    def __init__(self, seed: int = 0, steal_cost: Optional[float] = None):
        self.seed = seed
        self.steal_cost = steal_cost

    def reset(self, machine):
        self.rng = random.Random(self.seed)
        self.sp = self.steal_cost if self.steal_cost is not None else float(machine.b)
        self.waiting: list[tuple[float, int]] = []

    def on_idle(self, machine, core: int, t: float):
        self._attempt(machine, core, t)

    def on_task_available(self, machine, core: int, t: float):
        pass

    def flush(self, machine, t: float):
        # retry any waiting thieves
        waiting, self.waiting = self.waiting, []
        for since, thief in waiting:
            self._attempt(machine, thief, max(since, t))

    def _attempt(self, machine, thief: int, t: float):
        machine.stats.steal_attempts += 1
        victim = self.rng.randrange(machine.p)
        if victim == thief:
            victim = (victim + 1) % machine.p
        node = machine.steal_from(victim)
        if node is not None:
            pr = machine.prog.priority(node)
            machine.stats.steals.append((t, pr, thief, victim))
            machine.assign_stolen(thief, node, t + self.sp)
        else:
            self.waiting.append((t + 1.0, thief))
