"""HBP computation IR (paper Definitions 3.2–3.5).

A ``BPProgram`` describes the *structure and memory-access pattern* of a BP
computation: a balanced binary forking tree whose nodes perform O(1) work in
the down-pass head, O(1) in the up-pass, with leaves of O(1) work.  Concrete
algorithms subclass it and define the addresses touched (reads/writes) at
each node against a ``Memory`` bump allocator.

HBP composition (Def. 3.4): ``Sequence`` runs components one after another
(Type max(t1,t2)); ``Recurse``-style composition is expressed by programs
that expand into collections (see algorithms.py).

Validation helpers check the paper's structural requirements:
  * balance condition (Def. 3.2 vi): |task at level i| in [c1*a^i*r, c2*a^i*r]
  * limited access (Def. 2.4): every writable address written O(1) times
  * O(1) computation per node
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Iterable, Optional

Access = tuple[int, bool]  # (address, is_write)


class Memory:
    """Bump allocator over an abstract word-addressed memory.  The system
    property from §2.2 — core-requested space is block-aligned and disjoint —
    is enforced by aligning every allocation to the block size."""

    def __init__(self, block: int = 16):
        self.block = block
        self.top = 0
        self.regions: dict[str, tuple[int, int]] = {}

    def alloc(self, name: str, size: int) -> int:
        base = self.top
        self.regions[name] = (base, size)
        aligned = (size + self.block - 1) // self.block * self.block
        self.top += aligned
        return base


@dataclass
class Node:
    """One task in a BP tree.  ``lo..hi`` is the leaf range (size hi-lo)."""

    lo: int
    hi: int
    depth: int
    parent: Optional["Node"] = None
    left: Optional["Node"] = None
    right: Optional["Node"] = None
    join_count: int = 0
    frame_addr: int = -1  # assigned when the down-pass head executes
    stack_id: int = -1

    @property
    def size(self) -> int:
        return self.hi - self.lo

    @property
    def is_leaf(self) -> bool:
        return self.left is None


class BPProgram:
    """Base class: a single BP computation over ``n`` leaves (n power of 2).

    Subclasses override the access callbacks.  Sizes here are in leaves; the
    task size |tau| in words is proportional (each leaf touches O(1) words).
    """

    #: words of local variables per node frame (Def. 3.2 iv: O(1))
    frame_words: int = 2

    #: set by Machine.run_sequence so priorities never recur across sequenced
    #: components (Def. 3.4 case 4 + the Obs. 4.3 accounting)
    priority_offset: int = 0

    def __init__(self, n: int, name: str = "bp"):
        assert n > 0 and (n & (n - 1)) == 0, "n must be a power of two"
        self.n = n
        self.name = name
        self.root = self._build(0, n, 0, None)

    def _build(self, lo: int, hi: int, depth: int, parent) -> Node:
        node = Node(lo, hi, depth, parent)
        if hi - lo > 1:
            mid = (lo + hi) // 2
            node.left = self._build(lo, mid, depth + 1, node)
            node.right = self._build(mid, hi, depth + 1, node)
        return node

    # -- access callbacks (addresses in Memory space) -----------------------
    def head_accesses(self, node: Node) -> Iterable[Access]:
        return ()

    def leaf_accesses(self, node: Node) -> Iterable[Access]:
        return ()

    def up_accesses(self, node: Node) -> Iterable[Access]:
        return ()

    # -- padding (Def. 3.3) --------------------------------------------------
    def pad_words(self, node: Node) -> int:
        return 0

    # -- structural parameters ------------------------------------------------
    def nodes(self) -> Iterable[Node]:
        stack = [self.root]
        while stack:
            v = stack.pop()
            yield v
            if not v.is_leaf:
                stack.append(v.left)
                stack.append(v.right)

    def priority(self, node: Node) -> int:
        """PWS priority: -(DAG depth).  Strictly decreasing along any
        root-to-leaf path; in a balanced (H)BP computation all tasks at one
        priority have the same size to within a constant factor (§4.1/§4.2).
        Sequenced HBP components stack their depths (see algorithms.py and
        ``priority_offset``) so a priority never recurs across phases — the
        accounting behind Obs. 4.3's <= p-1 steals per priority."""
        return -node.depth - self.priority_offset


class PaddedBP(BPProgram):
    """Padded BP computation (Def. 3.3): each down-pass node declares an
    extra array of size sqrt(|tau|) on its execution stack."""

    def pad_words(self, node: Node) -> int:
        return int(math.isqrt(max(node.size, 1)))


@dataclass
class Sequence:
    """HBP sequencing (Def. 3.4, case 4): components run one after another,
    each itself a BPProgram or a Collection."""

    components: list
    name: str = "seq"


@dataclass
class Collection:
    """A BP/HBP collection: v parallel independent computations (generated by
    one level of parallel recursion).  The members are forked by a BP-like
    tree (paper §3.1 'Forking recursive tasks')."""

    members: list
    name: str = "coll"


# ---------------------------------------------------------------------------
# validators (paper's structural requirements)
# ---------------------------------------------------------------------------

def check_balance(prog: BPProgram, alpha: float = 0.5, c1: float = 0.5,
                  c2: float = 2.0) -> bool:
    """Def. 3.2 (vi): size of any task at level i within [c1 a^i r, c2 a^i r]."""
    r = prog.root.size
    for v in prog.nodes():
        bound = (alpha ** v.depth) * r
        if not (c1 * bound <= v.size <= c2 * bound):
            return False
    return True


def check_limited_access(prog: BPProgram, limit: int = 4) -> bool:
    """Def. 2.4: every writable address written O(1) (= ``limit``) times across
    the whole computation (global arrays; stack frames are reused space and
    are bounded separately by Lemma 3.1)."""
    writes: dict[int, int] = {}
    for v in prog.nodes():
        accesses = list(prog.head_accesses(v))
        accesses += list(prog.leaf_accesses(v)) if v.is_leaf else []
        accesses += list(prog.up_accesses(v)) if not v.is_leaf else []
        for addr, w in accesses:
            if w:
                writes[addr] = writes.get(addr, 0) + 1
                if writes[addr] > limit:
                    return False
    return True


def measure_cache_friendliness(prog: BPProgram, block: int) -> dict[int, float]:
    """Empirical f(r): for each task size r (per level), the max over tasks of
    (#distinct blocks touched) - |tau|/B, where |tau| = distinct words the
    task accesses (Def. 2.1: r words f-friendly if in O(r/B + f(r)) blocks)."""
    out: dict[int, float] = {}

    def footprint(v: Node) -> tuple[set[int], set[int]]:
        words: set[int] = set()
        blocks: set[int] = set()
        stack = [v]
        while stack:
            u = stack.pop()
            acc = list(prog.head_accesses(u))
            acc += list(prog.leaf_accesses(u)) if u.is_leaf else list(prog.up_accesses(u))
            for addr, _ in acc:
                words.add(addr)
                blocks.add(addr // block)
            if not u.is_leaf:
                stack.extend((u.left, u.right))
        return words, blocks

    level_nodes: dict[int, list[Node]] = {}
    for v in prog.nodes():
        level_nodes.setdefault(v.depth, []).append(v)
    for depth, nodes in level_nodes.items():
        r = nodes[0].size
        worst = 0.0
        for v in nodes[: 64]:  # sample
            words, blocks = footprint(v)
            worst = max(worst, len(blocks) - len(words) / block)
        out[r] = worst
    return out


def measure_block_sharing(prog: BPProgram, block: int) -> dict[int, int]:
    """Empirical L(r): for each level, the max number of blocks a task shares
    with its OFF-SUBTREE concurrent tasks (Def. 2.3).  Computed on global
    arrays (frames are per-execution)."""

    def blocks_of(v: Node) -> set[int]:
        blocks: set[int] = set()
        stack = [v]
        while stack:
            u = stack.pop()
            acc = list(prog.head_accesses(u))
            acc += list(prog.leaf_accesses(u)) if u.is_leaf else list(prog.up_accesses(u))
            for addr, _ in acc:
                blocks.add(addr // block)
            if not u.is_leaf:
                stack.extend((u.left, u.right))
        return blocks

    level_nodes: dict[int, list[Node]] = {}
    for v in prog.nodes():
        level_nodes.setdefault(v.depth, []).append(v)
    out: dict[int, int] = {}
    for depth, nodes in sorted(level_nodes.items()):
        if len(nodes) < 2:
            continue
        r = nodes[0].size
        sets = [blocks_of(v) for v in nodes[: 32]]
        worst = 0
        for i, s in enumerate(sets):
            shared = set()
            for j, t in enumerate(sets):
                if i != j:
                    shared |= (s & t)
            worst = max(worst, len(shared))
        out[r] = worst
    return out
