"""Priority Work-Stealing scheduler (paper §4).

Deterministic: steals proceed in rounds of non-increasing priority
(priority = -depth, so larger tasks first — the size-based BFS order).  In
each round, idle cores are matched BY RANK to the available head tasks of
the round's priority (the distributed prefix-sums matching of §4.7); a steal
costs s_P = b * ceil(log2 p) (the two O(log p)-step tree phases of the
distributed implementation).

Properties the tests verify empirically (they are theorems in the paper):
  * at most p-1 tasks of any priority are stolen (Obs. 4.3);
  * steal priorities are non-increasing over time within a BP computation;
  * total steal attempts <= 2 p D' (Cor. 4.1).
"""
from __future__ import annotations

import math
from typing import Optional


def match_round(idle, heads):
    """One §4.7 distributed-matching round, as data: ``idle`` is a list of
    ``(rank, thief)`` pairs (rank = arrival order; ties by thief index) and
    ``heads`` a list of ``(victim, priority-or-None)`` queue heads.  Returns
    ``(best_priority, [(idle_pair, victim), ...])`` — the idle entries sorted
    by rank matched positionally to the victims holding the round's (max)
    priority, victims by index — or ``(None, [])`` when nothing is stealable.

    This is the deterministic core three consumers run their rounds
    through: the simulated-machine scheduler (:class:`PWS`), the serving
    engine's slot scheduler (``repro.launch.engine.SlotScheduler``) —
    requests are tasks, idle decode slots are thieves, priority = work
    remaining — and the fleet router's ``pws`` arm
    (``repro.launch.router.Router``), where whole replicas are the
    processors and queued requests the stealable heads.  The caller owns
    the round-boundary rules (advertised-bound deferral here; the
    bounded-steals cap in the engine and the router)."""
    live = [(v, pr) for v, pr in heads if pr is not None]
    if not live or not idle:
        return None, []
    best = max(pr for _, pr in live)
    victims = [v for v, pr in live if pr == best]
    return best, list(zip(sorted(idle), victims))


class PWS:
    def __init__(self, steal_cost: Optional[float] = None):
        self.steal_cost = steal_cost

    def reset(self, machine):
        self.idle: list[tuple[float, int]] = []  # (since, core)
        self.sp = self.steal_cost if self.steal_cost is not None else (
            machine.b * max(math.ceil(math.log2(max(machine.p, 2))), 1)
        )

    def on_idle(self, machine, core: int, t: float):
        self.idle.append((t, core))

    def on_task_available(self, machine, core: int, t: float):
        pass  # matching happens at round boundaries (flush)

    def flush(self, machine, t: float):
        if self.idle:
            self._match(machine, t)

    def _match(self, machine, t: float):
        """Match idle cores to the highest-priority queue heads (round order).

        Paper §4.1/§4.7: a round with priority d only concludes when every
        non-idle core has generated a task on its queue; a busy core with an
        empty queue advertises (its current priority - 1) as an upper bound
        on the task it may yet generate, and the round DEFERS if that bound
        exceeds the best available head."""
        while self.idle:
            # the round's priority and pairing via the shared §4.7 round
            heads = [(v, machine.head_priority(v)) for v in range(machine.p)]
            best, pairs = match_round(self.idle, heads)
            if best is None:
                return
            # advertised upper bounds from busy cores with empty queues
            for c in range(machine.p):
                if machine.current[c] is not None and not machine.deques[c]:
                    node = machine.current[c][0]
                    adv = machine.prog.priority(node) - 1
                    if adv > best:
                        return  # round priority not yet determined — wait
            matched = 0
            for (since, thief), v in pairs:
                node = machine.steal_from(v)
                if node is None:
                    continue  # failed steal: thief stays idle for next round
                self.idle.remove((since, thief))
                machine.stats.steal_attempts += 1
                machine.stats.steals.append((t, best, thief, v))
                machine.assign_stolen(thief, node, max(t, since) + self.sp)
                matched += 1
            if matched == 0:
                return
