"""Closed-form cost bounds from the paper, used as test oracles.

Every function returns the *bound envelope* (up to the constant the caller
supplies); tests assert the simulator's measured counts fall below
``const * bound``.
"""
from __future__ import annotations

import math


def log2(x: float) -> float:
    return math.log2(max(x, 2.0))


def seq_cache_complexity_scan(n: int, M: int, B: int) -> float:
    """Q for scans: O(n/B)."""
    return n / B


def seq_cache_complexity_mt(n2: int, M: int, B: int) -> float:
    """Q for MT/RM<->BI on an n x n matrix (input size n2 = n^2): O(n^2/B)."""
    return n2 / B


def seq_cache_complexity_mm(m: int, k: int, n: int, M: int, B: int) -> float:
    """Q for classical tiled matmul (Depth-n-MM without Strassen):
    O(mkn / (B sqrt M) + (mk + kn + mn)/B) — the bound the kernel tile
    planner's block shapes must land inside."""
    return m * k * n / (B * math.sqrt(max(M, 1))) + (m * k + k * n + m * n) / B


def oblivious_tile_edge(M: int, n_arrays: int, itemsize: int) -> int:
    """The resource-oblivious square-tile envelope: a recursive HBP
    decomposition stops subdividing when its working set — ``n_arrays``
    square operand tiles of ``itemsize``-byte elements — fits in a cache of
    ``M`` bytes, i.e. edge = floor(sqrt(M / (n_arrays * itemsize))).  The
    kernel planner derives every block shape from this envelope with the
    *queried* device fast-memory size standing in for the unknown M."""
    return max(int(math.isqrt(max(M // max(n_arrays * itemsize, 1), 1))), 1)


def seq_cache_complexity_strassen(n: int, M: int, B: int) -> float:
    """Q = n^lambda / (B * M^(lambda/2 - 1)), lambda = log2 7 (§3.2)."""
    lam = math.log2(7)
    return n ** lam / (B * M ** (lam / 2 - 1))


def strassen_crossover_edge(M: int, B: int, *, min_edge: int = 128,
                            max_edge: int = 1 << 20) -> int:
    """Largest power-of-two square edge at which the classical Depth-n-MM
    envelope still wins against the Strassen one — i.e. the recursion cutoff
    for a Strassen-schedule matmul, and the edge *above* which the planner
    should pick the Strassen backend.

    Both envelopes get the same O(n^2/B) read/write term so the comparison
    is total modeled traffic; the leading terms then cross at n ~ sqrt(M)
    (below it the whole problem fits fast memory and classical is one pass).
    """
    edge = min_edge
    while edge < max_edge:
        n = 2 * edge
        lin = 3.0 * n * n / B
        if (seq_cache_complexity_strassen(n, M, B) + lin
                < seq_cache_complexity_mm(n, n, n, M, B)):
            break
        edge *= 2
    return edge


def seq_cache_complexity_fft(n: int, M: int, B: int) -> float:
    """Q = (n/B) log_M n."""
    return (n / B) * (math.log(n) / math.log(max(M, 2)))


def pws_cache_excess_bp(p: int, M: int, B: int) -> float:
    """Lemma 4.4(ii,iii): O(p M / B) for f(r)=O(sqrt r), M >= B^2."""
    return p * M / B


def pws_block_excess_bp(p: int, B: int, r: int) -> float:
    """Lemma 4.8(i): O(p B log B) for r >= B; O(p r log r) for r < B."""
    if r >= B:
        return p * B * log2(B)
    return p * r * log2(max(r, 2))


def pws_cache_excess_type2(p: int, M: int, B: int, n: int, *, c: int,
                           s_kind: str) -> float:
    """Lemma 4.1 for Type 2 HBP:
    (i) c=1: O(p M/B s*(n, M));
    (ii) c=2, s(n)=sqrt n: O(p M/B log n / log M);
    (iii) c=2, s(n)=n/4: O(p (sqrt(n M)/B + sqrt(n/M) * sqrt(M)))."""
    if c == 1:
        s_star = max(math.log2(max(n, 2)) / math.log2(max(M, 2)), 1.0)
        return p * M / B * s_star
    if s_kind == "sqrt":
        return p * (M / B) * (log2(n) / log2(M))
    return p * (math.sqrt(n * M) / B + math.sqrt(n / M) * math.sqrt(M))


def pws_block_excess_type2(p: int, B: int, n: int, *, c: int, s_kind: str) -> float:
    """Lemma 4.2: (i) c=1: O(p B log B s*(n));
    (ii) c=2, s=sqrt: O(p B log n log log B); (iii) c=2, s=n/4: O(p B sqrt n)."""
    if c == 1:
        return p * B * log2(B) * log2(n)
    if s_kind == "sqrt":
        return p * B * log2(n) * max(math.log2(max(log2(B), 2)), 1.0)
    return p * B * math.sqrt(n)


def steals_bound(p: int, n_priorities: int) -> int:
    """Obs. 4.3 + Cor. 4.1: <= (p-1) steals per priority,
    <= 2 p D' total attempts."""
    return 2 * p * n_priorities


def table1_asymptotics() -> dict[str, dict]:
    """Table 1 (for the benchmark report): structural parameters."""
    return {
        "scan": {"type": 1, "f": "1", "L": "1", "W": "n", "T_inf": "log n", "Q": "n/B"},
        "mt": {"type": 1, "f": "1", "L": "1", "W": "n^2", "T_inf": "log n", "Q": "n^2/B"},
        "strassen": {"type": 2, "f": "1", "L": "1", "W": "n^2.807", "T_inf": "log^2 n",
                     "Q": "n^l/(B M^(l/2-1))"},
        "rm_to_bi": {"type": 1, "f": "sqrt r", "L": "1", "W": "n^2", "T_inf": "log n",
                     "Q": "n^2/B"},
        "bi_to_rm_direct": {"type": 1, "f": "sqrt r", "L": "sqrt r", "W": "n^2",
                            "T_inf": "log n", "Q": "n^2/B"},
        "bi_to_rm_gap": {"type": 1, "f": "sqrt r", "L": "gap", "W": "n^2",
                         "T_inf": "log n", "Q": "n^2/B"},
        "fft": {"type": 2, "f": "sqrt r", "L": "1", "W": "n log n",
                "T_inf": "log n loglog n", "Q": "(n/B) log_M n"},
        "lr": {"type": 3, "f": "sqrt r", "L": "gap", "W": "n log n",
               "T_inf": "log^2 n loglog n", "Q": "(n/B) log_M n"},
        "cc": {"type": 4, "f": "sqrt r", "L": "gap", "W": "n log^2 n",
               "T_inf": "log^3 n loglog n", "Q": "(n/B) log_M n log n"},
    }
