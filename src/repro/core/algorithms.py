"""The paper's HBP algorithms as simulator programs (access-trace level).

Each program subclasses ``BPProgram`` and defines the global-array addresses
its tasks touch; the simulated machine (``repro.core.machine``) replays them
under PWS/RWS and counts cache misses, block misses, steals per priority.

Programs here (Table 1):
  * MSum / MA           — scans (Type 1, f=1, L=1)
  * PrefixSums          — two-pass PS (Type 1 sequence)
  * MTBI                — matrix transpose in BI layout (f=1, L=1)
  * RMtoBI              — f=sqrt r reads, L=1 writes
  * BItoRMDirect        — f=sqrt r, L=sqrt r  (block misses!)
  * BItoRMGapped        — the gapping technique: hierarchical gaps kill
                          write-block sharing for tasks >= B log^2 B
  * StrassenSim         — Type 2 HBP with SEQ/FORK nodes (7-way recursion,
                          MA collections before/after, fresh temporaries =
                          limited access)

Value-level (numerically exact) twins live in ``algorithms_jax.py``.
"""
from __future__ import annotations

import math

import numpy as np

from repro.core import layouts
from repro.core.hbp import BPProgram, Memory, Node


# ---------------------------------------------------------------------------
# Type 1: scans
# ---------------------------------------------------------------------------

class MSum(BPProgram):
    """Sum of A[0..n): the paper's M-Sum.  Output values stored in the
    in-order up-tree layout (§3.3) so up-pass writes never share blocks above
    level log B."""

    def __init__(self, n: int, mem: Memory, name: str = "msum",
                 input_base: int | None = None):
        self.mem = mem
        self.A = mem.alloc(f"{name}.A", n) if input_base is None else input_base
        self.S = mem.alloc(f"{name}.S", 2 * n)  # in-order layout sums
        self._inorder = layouts.inorder_positions(n)
        super().__init__(n, name)

    def _pos(self, node: Node) -> int:
        level = int(math.log2(max(node.size, 1)))
        idx = node.lo >> level
        return self._inorder[(level, idx)]

    def leaf_accesses(self, node: Node):
        return [(self.A + node.lo, False), (self.S + self._pos(node), True)]

    def up_accesses(self, node: Node):
        return [
            (self.S + self._pos(node.left), False),
            (self.S + self._pos(node.right), False),
            (self.S + self._pos(node), True),
        ]


class PSDistribute(BPProgram):
    """Second PS pass: distribute prefix offsets down the tree and write
    OUT[i] = offset_i + A[i].  Reads the in-order sums of a prior MSum."""

    def __init__(self, msum: MSum, mem: Memory, name: str = "psdist"):
        self.msum = msum
        self.OUT = mem.alloc(f"{name}.OUT", msum.n)
        super().__init__(msum.n, name)

    def head_accesses(self, node: Node):
        if node.is_leaf:
            return ()
        # read left child's subtree sum to pass offset to the right child
        return [(self.msum.S + self.msum._pos(node.left), False)]

    def leaf_accesses(self, node: Node):
        return [(self.msum.A + node.lo, False), (self.OUT + node.lo, True)]


def prefix_sums_programs(n: int, mem: Memory):
    m = MSum(n, mem)
    return [m, PSDistribute(m, mem)]


# ---------------------------------------------------------------------------
# Type 1: matrix programs (input size n^2; BP over the BI index space)
# ---------------------------------------------------------------------------

class MTBI(BPProgram):
    """In-place transpose of an n x n matrix in BI layout.  Leaf z with
    coords (r, c): if r < c, swap A[z] and A[z(c,r)]; else no-op.  Every
    address written once (limited access); subtree ranges are BI-contiguous
    (f = O(1)); the mirror range is touched by no other active task
    (L = O(1))."""

    def __init__(self, n_mat: int, mem: Memory, name: str = "mtbi"):
        self.n_mat = n_mat
        self.A = mem.alloc(f"{name}.A", n_mat * n_mat)
        super().__init__(n_mat * n_mat, name)

    def leaf_accesses(self, node: Node):
        z = node.lo
        r, c = layouts.bi_coords(np.asarray([z]))
        r, c = int(r[0]), int(c[0])
        if r >= c:
            return ()
        z2 = int(layouts.bi_index(np.asarray([c]), np.asarray([r]))[0])
        return [(self.A + z, False), (self.A + z2, False),
                (self.A + z, True), (self.A + z2, True)]


class RMtoBI(BPProgram):
    """BI[z] = RM[r,c]: contiguous writes (L=1), scattered reads (f=sqrt r)."""

    def __init__(self, n_mat: int, mem: Memory, name: str = "rm2bi"):
        self.n_mat = n_mat
        self.RM = mem.alloc(f"{name}.RM", n_mat * n_mat)
        self.BI = mem.alloc(f"{name}.BI", n_mat * n_mat)
        z = np.arange(n_mat * n_mat)
        r, c = layouts.bi_coords(z)
        self._rm_off = (r.astype(np.int64) * n_mat + c.astype(np.int64))
        super().__init__(n_mat * n_mat, name)

    def leaf_accesses(self, node: Node):
        z = node.lo
        return [(self.RM + int(self._rm_off[z]), False), (self.BI + z, True)]


class BItoRMDirect(BPProgram):
    """RM[r,c] = BI[z]: scattered WRITES -> L(r) = sqrt(r): concurrent tasks
    write into the same RM row blocks => block misses under stealing."""

    def __init__(self, n_mat: int, mem: Memory, name: str = "bi2rm"):
        self.n_mat = n_mat
        self.BI = mem.alloc(f"{name}.BI", n_mat * n_mat)
        self.RM = mem.alloc(f"{name}.RM", n_mat * n_mat)
        z = np.arange(n_mat * n_mat)
        r, c = layouts.bi_coords(z)
        self._rm_off = (r.astype(np.int64) * n_mat + c.astype(np.int64))
        super().__init__(n_mat * n_mat, name)

    def leaf_accesses(self, node: Node):
        z = node.lo
        return [(self.BI + z, False), (self.RM + int(self._rm_off[z]), True)]


def _hierarchical_gap_offset(c: np.ndarray, n: int) -> np.ndarray:
    """Column offset with the paper's hierarchical gaps: after every
    2^i-aligned segment (4 <= 2^i <= n), insert gap_for(2^i) empty words."""
    off = c.astype(np.int64).copy()
    i = 2
    while (1 << i) <= n:
        seg = 1 << i
        off += (c // seg).astype(np.int64) * layouts.gap_for(seg)
        i += 1
    return off


class BItoRMGapped(BPProgram):
    """BI->RM with the gapping technique (§3.2 'BI-RM (gap RM)'): the RM
    destination has hierarchical gaps so tasks of size >= ~B log^2 B share no
    write blocks.  A compaction scan (Type 1, f=L=1) follows."""

    def __init__(self, n_mat: int, mem: Memory, name: str = "bi2rmgap"):
        self.n_mat = n_mat
        n2 = n_mat * n_mat
        self.BI = mem.alloc(f"{name}.BI", n2)
        z = np.arange(n2)
        r, c = bi_r, bi_c = layouts.bi_coords(z)
        col_off = _hierarchical_gap_offset(np.arange(n_mat), n_mat)
        row_len = int(col_off[-1]) + 1 + layouts.gap_for(n_mat)
        row_off = _hierarchical_gap_offset(np.arange(n_mat), n_mat) * row_len
        self.row_len = row_len
        self.GAP = mem.alloc(f"{name}.GAP", int(row_off[-1]) + row_len + 1)
        self._dst = (row_off[r.astype(np.int64)] + col_off[c.astype(np.int64)])
        super().__init__(n2, name)

    def leaf_accesses(self, node: Node):
        z = node.lo
        return [(self.BI + z, False), (self.GAP + int(self._dst[z]), True)]


class CompactScan(BPProgram):
    """Compact the gapped array back to dense RM (a standard scan)."""

    def __init__(self, gapped: BItoRMGapped, mem: Memory, name: str = "compact"):
        self.g = gapped
        n2 = gapped.n
        self.RM = mem.alloc(f"{name}.RM", n2)
        n_mat = gapped.n_mat
        r, c = np.divmod(np.arange(n2), n_mat)
        col_off = _hierarchical_gap_offset(np.arange(n_mat), n_mat)
        row_off = _hierarchical_gap_offset(np.arange(n_mat), n_mat) * gapped.row_len
        self._src = row_off[r] + col_off[c]
        super().__init__(n2, name)

    def leaf_accesses(self, node: Node):
        i = node.lo
        return [(self.g.GAP + int(self._src[i]), False), (self.RM + i, True)]


def bi_to_rm_gapped_programs(n_mat: int, mem: Memory):
    g = BItoRMGapped(n_mat, mem)
    return [g, CompactScan(g, mem)]


# ---------------------------------------------------------------------------
# Type 2: Strassen (SEQ/FORK composite tree)
# ---------------------------------------------------------------------------

class CompositeProgram(BPProgram):
    """A program whose tree contains SEQ nodes (children run in order) in
    addition to binary fork nodes.  Used for Type >= 2 HBP computations.
    The machine executes SEQ nodes by running children sequentially."""

    def __init__(self, root: Node, n: int, name: str):
        self.n = n
        self.name = name
        self.root = root
        self._leaf_acc: dict[int, list] = {}
        self._up_acc: dict[int, list] = {}

    # access maps keyed by id(node)
    def leaf_accesses(self, node: Node):
        return self._leaf_acc.get(id(node), ())

    def up_accesses(self, node: Node):
        return self._up_acc.get(id(node), ())

    def priority(self, node: Node) -> int:
        return -node.depth


def _fork_tree(leaves: list[Node], depth: int, parent: Node | None) -> Node:
    """Binary fork tree over an arbitrary list of subtree roots."""
    if len(leaves) == 1:
        leaves[0].depth = depth
        leaves[0].parent = parent
        _renumber(leaves[0])
        return leaves[0]
    mid = (len(leaves) + 1) // 2
    node = Node(leaves[0].lo, leaves[-1].hi, depth, parent)
    node.left = _fork_tree(leaves[:mid], depth + 1, node)
    node.right = _fork_tree(leaves[mid:], depth + 1, node)
    node.left.parent = node
    node.right.parent = node
    return node


def _renumber(root: Node):
    stack = [root]
    while stack:
        v = stack.pop()
        seq = getattr(v, "seq_children", None)
        if seq is not None:
            # sequenced components stack their depth ranges so priorities
            # never recur across phases (see BPProgram.priority)
            d = v.depth + 1
            for ch in seq:
                ch.parent = v
                ch.depth = d
                _renumber(ch)
                d += _height(ch) + 1
        elif not v.is_leaf:
            v.left.depth = v.depth + 1
            v.right.depth = v.depth + 1
            stack.extend((v.left, v.right))


def _height(root: Node) -> int:
    cached = getattr(root, "_height_cache", None)
    if cached is not None:
        return cached
    seq = getattr(root, "seq_children", None)
    if seq is not None:
        h = sum(_height(ch) + 1 for ch in seq)
    elif root.is_leaf:
        h = 0
    else:
        h = 1 + max(_height(root.left), _height(root.right))
    root._height_cache = h  # type: ignore[attr-defined]
    return h


def _ma_tree(prog: CompositeProgram, dst: int, srcs: list[int], size: int,
             depth: int) -> Node:
    """BP tree computing dst[i] = combine(srcs[i]) for i in [0, size)."""

    def build(lo, hi, d, parent):
        node = Node(lo, hi, d, parent)
        if hi - lo > 1:
            mid = (lo + hi) // 2
            node.left = build(lo, mid, d + 1, node)
            node.right = build(mid, hi, d + 1, node)
        else:
            acc = [(s + lo, False) for s in srcs] + [(dst + lo, True)]
            prog._leaf_acc[id(node)] = acc
        return node

    return build(0, size, depth, None)


# Strassen products:  M1=(A11+A22)(B11+B22), M2=(A21+A22)B11, M3=A11(B12-B22),
# M4=A22(B21-B11), M5=(A11+A12)B22, M6=(A21-A11)(B11+B12), M7=(A12-A22)(B21+B22)
_STRASSEN_LHS = [(0, 3), (2, 3), (0,), (3,), (0, 1), (2, 0), (1, 3)]
_STRASSEN_RHS = [(0, 3), (0,), (1, 3), (2, 0), (3,), (0, 1), (2, 3)]
# C11 = M1+M4-M5+M7, C12 = M3+M5, C21 = M2+M4, C22 = M1-M2+M3+M6
_STRASSEN_OUT = [(0, 3, 4, 6), (2, 4), (1, 3), (0, 1, 2, 5)]


def strassen_program(n_mat: int, mem: Memory, base: int = 4) -> CompositeProgram:
    """Build the full Strassen HBP task tree (Type 2: c=1 collection of v=7
    subproblems of size m/4, MA collections before and after, all results in
    fresh arrays => limited access).  Matrices in BI layout, so quadrant q of
    a BI matrix of n^2 elements is the contiguous range [q*n^2/4, (q+1)*n^2/4)."""
    prog = CompositeProgram.__new__(CompositeProgram)
    prog._leaf_acc = {}
    prog._up_acc = {}
    prog.name = "strassen"
    prog.n = n_mat * n_mat

    A = mem.alloc("str.A", n_mat * n_mat)
    B = mem.alloc("str.B", n_mat * n_mat)
    C = mem.alloc("str.C", n_mat * n_mat)

    counter = [0]

    def rec(a: int, b: int, c: int, n: int, depth: int) -> Node:
        n2 = n * n
        if n <= base:
            # base-case MM as a BP tree over output elements
            def build(lo, hi, d, parent):
                node = Node(lo, hi, d, parent)
                if hi - lo > 1:
                    mid = (lo + hi) // 2
                    node.left = build(lo, mid, d + 1, node)
                    node.right = build(mid, hi, d + 1, node)
                else:
                    i, j = divmod(lo, n)
                    acc = [(a + i * n + kk, False) for kk in range(n)]
                    acc += [(b + kk * n + j, False) for kk in range(n)]
                    acc += [(c + lo, True)]
                    prog._leaf_acc[id(node)] = acc
                return node

            return build(0, n2, depth, None)

        q = n2 // 4  # BI quadrant stride
        Aq = [a + i * q for i in range(4)]
        Bq = [b + i * q for i in range(4)]
        Cq = [c + i * q for i in range(4)]
        counter[0] += 1
        tag = counter[0]

        pre: list[Node] = []
        lhs_bases, rhs_bases, t_bases = [], [], []
        for i in range(7):
            lb = mem.alloc(f"str.L{tag}.{i}", q)
            rb = mem.alloc(f"str.R{tag}.{i}", q)
            tb = mem.alloc(f"str.T{tag}.{i}", q)
            lhs_bases.append(lb)
            rhs_bases.append(rb)
            t_bases.append(tb)
            pre.append(_ma_tree(prog, lb, [Aq[k] for k in _STRASSEN_LHS[i]], q, 0))
            pre.append(_ma_tree(prog, rb, [Bq[k] for k in _STRASSEN_RHS[i]], q, 0))
        pre_root = _fork_tree(pre, 0, None)

        recs = [rec(lhs_bases[i], rhs_bases[i], t_bases[i], n // 2, 0)
                for i in range(7)]
        rec_root = _fork_tree(recs, 0, None)

        post = [_ma_tree(prog, Cq[j], [t_bases[k] for k in _STRASSEN_OUT[j]], q, 0)
                for j in range(4)]
        post_root = _fork_tree(post, 0, None)

        seq = Node(0, n2, depth, None)
        seq.seq_children = [pre_root, rec_root, post_root]  # type: ignore[attr-defined]
        for ch in seq.seq_children:  # type: ignore[attr-defined]
            ch.parent = seq
        return seq

    prog.root = rec(A, B, C, n_mat, 0)
    _renumber(prog.root)
    return prog


# ---------------------------------------------------------------------------
# Type 2: six-step FFT (structure-level: MT + sqrt(n) recursive FFTs + MT)
# ---------------------------------------------------------------------------

def fft_program(n: int, mem: Memory, base: int = 16) -> CompositeProgram:
    """The paper's FFT (§3.2): view length-n input as a sqrt(n) x sqrt(n)
    matrix (BI layout), transpose (MT), run sqrt(n) recursive FFTs of size
    sqrt(n) in parallel, twiddle-scale (a scan), transpose again.  Type 2
    HBP with c=2 collections of v=sqrt(n) subproblems of size sqrt(n).

    Access-trace level: the simulator counts the misses; the value-level
    twin is algorithms_jax.fft_six_step."""
    import math as _m

    prog = CompositeProgram.__new__(CompositeProgram)
    prog._leaf_acc = {}
    prog._up_acc = {}
    prog.name = "fft"
    prog.n = n
    X = mem.alloc("fft.X", n)

    def mt_tree(base_addr: int, m_side: int) -> Node:
        """BI transpose of an m_side x m_side region starting at base_addr."""
        n2 = m_side * m_side

        def build(lo, hi, d, parent):
            node = Node(lo, hi, d, parent)
            if hi - lo > 1:
                mid = (lo + hi) // 2
                node.left = build(lo, mid, d + 1, node)
                node.right = build(mid, hi, d + 1, node)
            else:
                z = lo
                r, c = layouts.bi_coords(np.asarray([z]))
                r, c = int(r[0]), int(c[0])
                if r < c:
                    z2 = int(layouts.bi_index(np.asarray([c]), np.asarray([r]))[0])
                    prog._leaf_acc[id(node)] = [
                        (base_addr + z, False), (base_addr + z2, False),
                        (base_addr + z, True), (base_addr + z2, True)]
            return node

        return build(0, n2, 0, None)

    def scan_tree(base_addr: int, size: int) -> Node:
        """Twiddle scale: read+write each element once (a BP scan)."""

        def build(lo, hi, d, parent):
            node = Node(lo, hi, d, parent)
            if hi - lo > 1:
                mid = (lo + hi) // 2
                node.left = build(lo, mid, d + 1, node)
                node.right = build(mid, hi, d + 1, node)
            else:
                prog._leaf_acc[id(node)] = [(base_addr + lo, False),
                                            (base_addr + lo, True)]
            return node

        return build(0, size, 0, None)

    def transpose_comp(base_addr: int, size: int) -> Node:
        """Square regions use the BI MT tree; rectangular splits fall back to
        a one-read-one-write pass (same f=O(1)/L=O(1) cost class in BI)."""
        m_side = int(_m.isqrt(size))
        if m_side * m_side == size:
            return mt_tree(base_addr, m_side)
        return scan_tree(base_addr, size)

    def rec(base_addr: int, size: int, depth: int) -> Node:
        if size <= base:
            return scan_tree(base_addr, size)  # base-case butterfly pass
        # view as rows x cols with cols = 2^ceil(log2(size)/2)
        cols = 1 << ((size.bit_length()) // 2)
        rows = size // cols
        subs1 = [rec(base_addr + i * cols, cols, 0) for i in range(rows)]
        subs2 = [rec(base_addr + i * rows, rows, 0) for i in range(cols)]
        seq = Node(0, size, depth, None)
        seq.seq_children = [  # type: ignore[attr-defined]
            transpose_comp(base_addr, size),
            _fork_tree(subs1, 0, None),
            scan_tree(base_addr, size),  # twiddles
            transpose_comp(base_addr, size),
            _fork_tree(subs2, 0, None),
            transpose_comp(base_addr, size),
        ]
        for ch in seq.seq_children:  # type: ignore[attr-defined]
            ch.parent = seq
        return seq

    prog.root = rec(X, n, 0)
    _renumber(prog.root)
    return prog


# ---------------------------------------------------------------------------
# Type 3: list ranking contraction phases with the paper's list gapping
# ---------------------------------------------------------------------------

def list_ranking_phase_programs(n: int, mem: Memory, *, gapped: bool = True):
    """The LR cost structure (§3.2/§4.6): geometric contraction phases; when
    the live list has size m = n/x^2 it is written in space n/x using every
    x-th location (the gapping), so once m <= n/B^2 no more block misses
    occur.  Each phase here is one BP pass over the live elements (the
    sort-free skeleton; SPMS cost shapes are validated in costmodel.py).

    Returns a list of BP programs (one per phase) sharing one array."""
    space = mem.alloc("lr.list", 2 * n)

    class PhaseProgram(BPProgram):
        def __init__(self, m: int, positions: np.ndarray, name: str):
            self.positions = positions
            super().__init__(m, name)

        def leaf_accesses(self, node: Node):
            p = int(self.positions[node.lo])
            return [(space + p, False), (space + p, True)]

    progs = []
    m = n
    while m >= 64:
        if gapped:
            pos = layouts.gapped_list_positions(m, n)
        else:
            pos = np.arange(m, dtype=np.int64)  # compact: adjacent phases share blocks
        progs.append(PhaseProgram(m, pos, f"lr_phase_{m}"))
        m //= 4  # a constant fraction eliminated per stage (paper: >= 1/3)
    return progs
