"""Value-level (numerically exact) JAX implementations of the paper's
algorithms.  Each is the *same algorithm* the simulator traces, but computing
real values — tests cross-check them against independent oracles
(jnp.cumsum, jnp.matmul, jnp.fft, numpy list ranking, union-find).

These also double as the CPU reference path for the Pallas kernels.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import layouts


# ---------------------------------------------------------------------------
# scans / prefix sums (two-pass BP, the paper's PS)
# ---------------------------------------------------------------------------

def prefix_sums(x: jax.Array, block: int = 128) -> jax.Array:
    """Inclusive prefix sums via the paper's two-BP-pass algorithm:
    pass 1 computes per-block sums + their exclusive scan (the up-tree),
    pass 2 distributes offsets into each block (the down-pass)."""
    n = x.shape[-1]
    block = min(block, n)
    if n % block != 0:
        pad = block - n % block
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    nb = x.shape[-1] // block
    xb = x.reshape(*x.shape[:-1], nb, block)
    local = jnp.cumsum(xb, axis=-1)
    block_tot = local[..., -1]
    offsets = jnp.cumsum(block_tot, axis=-1) - block_tot  # exclusive
    out = (local + offsets[..., None]).reshape(*x.shape[:-1], nb * block)
    return out[..., :n]


# ---------------------------------------------------------------------------
# BI layout ops
# ---------------------------------------------------------------------------

def rm_to_bi(m: jax.Array) -> jax.Array:
    n = m.shape[0]
    perm = jnp.asarray(layouts.rm_to_bi_perm(n))
    return m.reshape(-1)[perm]


def bi_to_rm(flat: jax.Array, n: int) -> jax.Array:
    perm = jnp.asarray(layouts.bi_to_rm_perm(n))
    return flat[perm].reshape(n, n)


def bi_to_rm_gapped(flat: jax.Array, n: int) -> jax.Array:
    """The gapped variant: scatter into the gapped buffer, then compact with
    a scan — value-identical to bi_to_rm; the gapping matters for block
    misses, which the simulator measures."""
    row_gap = layouts.gap_for(n)
    stride = n + row_gap
    z = jnp.arange(n * n)
    r, c = layouts.bi_coords(np.arange(n * n))
    dst = jnp.asarray(r.astype(np.int64) * stride + c.astype(np.int64))
    buf = jnp.zeros((n * stride,), flat.dtype).at[dst].set(flat[z])
    # compaction scan
    rr, cc = jnp.divmod(jnp.arange(n * n), n)
    return buf[rr * stride + cc].reshape(n, n)


def mt_bi(flat: jax.Array, n: int) -> jax.Array:
    """Transpose of a BI-layout matrix, staying in BI layout: permutation
    that swaps the row/col bit positions (pure index map — the BP tree's
    leaves)."""
    z = np.arange(n * n)
    r, c = layouts.bi_coords(z)
    swapped = layouts.bi_index(c, r)
    return flat[jnp.asarray(swapped.astype(np.int64))]


# ---------------------------------------------------------------------------
# Strassen
# ---------------------------------------------------------------------------

def strassen(a: jax.Array, b: jax.Array, leaf: int = 64) -> jax.Array:
    """Strassen matrix multiply (Type 2 HBP: 7 recursive subproblems computed
    into fresh arrays + MA combines => limited access)."""
    n = a.shape[0]
    if n <= leaf:
        return a @ b
    h = n // 2
    a11, a12, a21, a22 = a[:h, :h], a[:h, h:], a[h:, :h], a[h:, h:]
    b11, b12, b21, b22 = b[:h, :h], b[:h, h:], b[h:, :h], b[h:, h:]
    m1 = strassen(a11 + a22, b11 + b22, leaf)
    m2 = strassen(a21 + a22, b11, leaf)
    m3 = strassen(a11, b12 - b22, leaf)
    m4 = strassen(a22, b21 - b11, leaf)
    m5 = strassen(a11 + a12, b22, leaf)
    m6 = strassen(a21 - a11, b11 + b12, leaf)
    m7 = strassen(a12 - a22, b21 + b22, leaf)
    c11 = m1 + m4 - m5 + m7
    c12 = m3 + m5
    c21 = m2 + m4
    c22 = m1 - m2 + m3 + m6
    return jnp.concatenate(
        [jnp.concatenate([c11, c12], axis=1), jnp.concatenate([c21, c22], axis=1)],
        axis=0,
    )


# ---------------------------------------------------------------------------
# six-step FFT (Bailey / the paper's FFT)
# ---------------------------------------------------------------------------

def fft_six_step(x: jax.Array) -> jax.Array:
    """FFT of length n = m^2 via the six-step algorithm:
    1. view as m x m, transpose; 2. m FFTs of size m (rows);
    3. twiddle; 4. transpose; 5. m FFTs of size m; 6. transpose.
    Row FFTs recurse on sub-square sizes (here: one level, rows via
    jnp.fft.fft of size m — the recursion bottoms out immediately since the
    parallel structure, not the butterfly, is what the paper contributes)."""
    n = x.shape[-1]
    m = int(math.isqrt(n))
    assert m * m == n, "six-step FFT needs n = m^2"
    a = x.reshape(m, m)  # step 0: view as matrix (row-major: a[i,j] = x[i*m+j])
    a = a.T  # 1. transpose
    a = jnp.fft.fft(a, axis=-1)  # 2. row FFTs
    ij = jnp.outer(jnp.arange(m), jnp.arange(m))
    tw = jnp.exp(-2j * jnp.pi * ij / n)  # 3. twiddles
    a = a * tw
    a = a.T  # 4. transpose
    a = jnp.fft.fft(a, axis=-1)  # 5. row FFTs
    a = a.T  # 6. transpose
    return a.reshape(n)


# ---------------------------------------------------------------------------
# list ranking (IS-contraction + pointer jumping, with gapping)
# ---------------------------------------------------------------------------

def list_ranking(succ: np.ndarray) -> np.ndarray:
    """Rank (distance to the end) of each element of a linked list given
    successor pointers (succ[i] = next of i, terminal points to itself).

    Parallel-structure-faithful implementation: O(log log n) contraction
    stages removing independent sets of non-adjacent elements (2-coloring by
    random bits = the O(log^(k) n) coloring of MO-IS), then pointer jumping
    on the contracted list, then rank reinstatement in reverse.  Runs in
    numpy for test-oracle clarity."""
    n = len(succ)
    succ = succ.copy()
    dist = np.ones(n, dtype=np.int64)
    terminal = np.flatnonzero(succ == np.arange(n))
    dist[terminal] = 0

    rng = np.random.default_rng(0)
    alive = np.ones(n, dtype=bool)
    removed_stack: list[np.ndarray] = []
    threshold = max(n // max(int(math.log2(max(n, 2))), 1), 64)

    while alive.sum() > threshold:
        # independent set: heads of "tails": coin flip per element;
        # pick i with coin[i]=1 and coin[succ[i]]=0, i not terminal
        coin = rng.integers(0, 2, n).astype(bool)
        is_term = succ == np.arange(n)
        sel = alive & coin & ~coin[succ] & ~is_term & ~is_term[succ]
        # no two adjacent selected: if sel[i], then coin[succ[i]]=0 => not sel[succ[i]]
        idx = np.flatnonzero(sel)
        if len(idx) == 0:
            continue
        # splice out: pred of i points to succ[i].  Find preds of selected.
        pred = np.full(n, -1, dtype=np.int64)
        valid = alive & (succ != np.arange(n))
        pred[succ[np.flatnonzero(valid)]] = np.flatnonzero(valid)
        has_pred = pred[idx] >= 0
        p_idx = pred[idx[has_pred]]
        # bypass: succ[pred[i]] = succ[i]; dist[pred[i]] += dist[i]
        succ[p_idx] = succ[idx[has_pred]]
        dist[p_idx] = dist[p_idx] + dist[idx[has_pred]]
        alive[idx] = False
        removed_stack.append(idx)

    # pointer jumping (doubling) on the contracted list:
    # rank[i] = distance to terminal; invariant after k rounds: rank[i] is
    # the distance covered by following nxt 2^k times (capped at terminal)
    rank = np.where(succ == np.arange(n), 0, dist)
    nxt = succ.copy()
    for _ in range(int(math.ceil(math.log2(max(n, 2)))) + 1):
        rank = rank + np.where(nxt == np.arange(n), 0, rank[nxt])
        nxt = nxt[nxt]

    # reinstate removed elements in reverse order
    for idx in reversed(removed_stack):
        rank[idx] = rank[succ[idx]] + dist[idx]
    return rank


def list_ranking_oracle(succ: np.ndarray) -> np.ndarray:
    """Sequential oracle: walk from the terminal backwards."""
    n = len(succ)
    rank = np.zeros(n, dtype=np.int64)
    pred = np.full(n, -1, dtype=np.int64)
    for i in range(n):
        if succ[i] != i:
            pred[succ[i]] = i
    term = int(np.flatnonzero(succ == np.arange(n))[0])
    r = 0
    cur = term
    while pred[cur] >= 0:
        r += 1
        cur = pred[cur]
        rank[cur] = r
    return rank


# ---------------------------------------------------------------------------
# connected components (hook & contract over the LR primitives)
# ---------------------------------------------------------------------------

def connected_components(n: int, edges: np.ndarray) -> np.ndarray:
    """Label propagation / hook-and-contract: O(log n) stages, each stage =
    scans + 'pointer jumping' (shortcutting) — the structure the paper counts
    as log n stages of list-ranking-like work.  Returns component labels."""
    label = np.arange(n, dtype=np.int64)
    if len(edges) == 0:
        return label
    u, v = edges[:, 0], edges[:, 1]
    for _ in range(int(math.ceil(math.log2(max(n, 2)))) * 2 + 2):
        # hook: point each root to the min neighbor label
        lu, lv = label[u], label[v]
        m = np.minimum(lu, lv)
        new = label.copy()
        np.minimum.at(new, lu, m)
        np.minimum.at(new, lv, m)
        # shortcut (pointer jumping)
        for _ in range(2):
            new = new[new]
        if np.array_equal(new, label):
            break
        label = new
    return label
