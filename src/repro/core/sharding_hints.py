"""Logical-axis sharding hints.

Models remain resource-oblivious (the paper's contract: algorithms never
mention p, M, B).  They annotate tensors with *logical* axis names
("batch", "heads", "ffn", "experts", "vocab"); the launcher binds logical
names to mesh axes before tracing.  Outside a binding context the hints are
no-ops, so unit tests and single-device runs are untouched.

This is the activation-side half of the PWS planner: the weight-side half
lives in ``repro.core.planner``.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional, Union

import jax
from jax.sharding import PartitionSpec as P

# logical name -> mesh axis (str or tuple) binding
_RULES: contextvars.ContextVar[Optional[dict]] = contextvars.ContextVar(
    "sharding_rules", default=None
)

UNCONSTRAINED = P.UNCONSTRAINED


@contextlib.contextmanager
def axis_rules(rules: dict[str, Union[str, tuple, None]], mesh=None):
    """Bind logical axis names to mesh axes.  Example:
    ``axis_rules({"batch": ("pod", "data"), "heads": "model", ...}, mesh)``."""
    sizes = dict(mesh.shape) if mesh is not None else {}
    token = _RULES.set({"rules": dict(rules), "sizes": sizes})
    try:
        yield
    finally:
        _RULES.reset(token)


def default_rules(mesh) -> dict:
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    return {
        "batch": dp,
        "heads": "model",
        "kv_heads": "model",
        "ffn": "model",
        "experts": "model",
        "vocab": "model",
        # Megatron-style sequence parallelism on the residual stream: the
        # per-layer saved activations shrink by |tp|; wire bytes equal the
        # pure-TP all-reduce (AR 2S == AG S + RS S).  Decode (s=1) demotes
        # to unconstrained automatically via the divisibility rule.
        "seq": "model",
    }


def constrain(x: jax.Array, *logical_axes) -> jax.Array:
    """Apply with_sharding_constraint following the bound rules.

    Entries: logical name (str), "*" (unconstrained), or None (replicated).
    Axes that do not divide the dimension are demoted to unconstrained —
    the paper's balance condition: only balanced forks are stolen.
    """
    ctx = _RULES.get()
    if ctx is None:
        return x
    rules, sizes = ctx["rules"], ctx["sizes"]
    if not sizes:
        return x
    entries = []
    for dim, name in zip(x.shape, logical_axes):
        if name == "*":
            entries.append(UNCONSTRAINED)
            continue
        if name is None:
            entries.append(None)
            continue
        axis = rules.get(name, "*")
        if axis == "*" or axis is None:
            entries.append(UNCONSTRAINED if axis == "*" else None)
            continue
        ax_tuple = axis if isinstance(axis, tuple) else (axis,)
        size = 1
        for a in ax_tuple:
            size *= sizes.get(a, 1)
        if size > 1 and dim % size == 0:
            entries.append(axis)
        else:
            entries.append(UNCONSTRAINED)
    if all(e is UNCONSTRAINED for e in entries):
        return x
    return jax.lax.with_sharding_constraint(x, P(*entries))
