"""Data layouts from the paper: bit-interleaved (BI / Morton / Z-order),
RM<->BI conversion index maps, gapped arrays, and the in-order up-pass
output layout.

These are used three ways:
  1. by the simulator (``repro.core.machine``) to generate access traces;
  2. by the value-level JAX algorithms (``repro.core.algorithms``);
  3. conceptually by the kernels: ``repro.kernels.bi_transpose`` enumerates
     MXU tiles in Morton order (the TPU realization of BI).
"""
from __future__ import annotations

import math

import numpy as np


# ---------------------------------------------------------------------------
# bit interleaving (Morton / Z-order)
# ---------------------------------------------------------------------------

def _part1by1(x: np.ndarray) -> np.ndarray:
    """Spread the low 16 bits of x to even bit positions (vectorized)."""
    x = x.astype(np.uint32)
    x = (x | (x << 8)) & np.uint32(0x00FF00FF)
    x = (x | (x << 4)) & np.uint32(0x0F0F0F0F)
    x = (x | (x << 2)) & np.uint32(0x33333333)
    x = (x | (x << 1)) & np.uint32(0x55555555)
    return x


def _compact1by1(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint32) & np.uint32(0x55555555)
    x = (x | (x >> 1)) & np.uint32(0x33333333)
    x = (x | (x >> 2)) & np.uint32(0x0F0F0F0F)
    x = (x | (x >> 4)) & np.uint32(0x00FF00FF)
    x = (x | (x >> 8)) & np.uint32(0x0000FFFF)
    return x


def bi_index(row, col) -> np.ndarray:
    """Z-order index: row bits to odd positions, col bits to even.
    The recursive quadrant order is (TL, TR, BL, BR) as in the paper."""
    return (_part1by1(np.asarray(row)) << 1) | _part1by1(np.asarray(col))


def bi_coords(z) -> tuple[np.ndarray, np.ndarray]:
    z = np.asarray(z)
    return _compact1by1(z >> 1), _compact1by1(z)


def rm_to_bi_perm(n: int) -> np.ndarray:
    """perm such that flat_bi[bi_index(r,c)] = rm[r,c]:
    returns indices p with flat_bi = rm.flatten()[p]."""
    z = np.arange(n * n)
    r, c = bi_coords(z)
    return (r * n + c).astype(np.int64)


def bi_to_rm_perm(n: int) -> np.ndarray:
    """inverse permutation: rm.flatten() = flat_bi[p]."""
    r, c = np.divmod(np.arange(n * n), n)
    return bi_index(r, c).astype(np.int64)


def rm_to_bi(m: np.ndarray) -> np.ndarray:
    n = m.shape[0]
    return m.reshape(-1)[rm_to_bi_perm(n)]


def bi_to_rm(flat: np.ndarray, n: int) -> np.ndarray:
    return flat[bi_to_rm_perm(n)].reshape(n, n)


# ---------------------------------------------------------------------------
# gapping (paper §3.2, BI->RM (gap RM) and LR list gapping)
# ---------------------------------------------------------------------------

def gap_for(r: int) -> int:
    """Row gap r/log^2 r for a size-r row (>= 0); the paper shows the total
    expansion is a constant factor since sum over r=2^i of 1/log^2 r = O(1)."""
    if r < 4:
        return 0
    return max(int(r / (math.log2(r) ** 2)), 1)


def gapped_row_starts(n: int) -> np.ndarray:
    """Start offset of each row in the gapped RM destination (gap = gap_for(n)
    between rows)."""
    stride = n + gap_for(n)
    return np.arange(n, dtype=np.int64) * stride


def gapped_size(n: int) -> int:
    return int(n * (n + gap_for(n)))


def gapped_list_positions(m: int, n: int) -> np.ndarray:
    """Paper's LR gapping: a contracted list of size m <= n is written in
    space n/x using every x-th location, where m = n/x^2 (so x = sqrt(n/m)).
    Returns the m write positions."""
    if m >= n:
        return np.arange(m, dtype=np.int64)
    x = max(int(math.isqrt(n // max(m, 1))), 1)
    return (np.arange(m, dtype=np.int64) * x)


# ---------------------------------------------------------------------------
# in-order up-pass output layout (paper §3.3 "Data Layout in a BP Computation")
# ---------------------------------------------------------------------------

def inorder_positions(n_leaves: int) -> dict[tuple[int, int], int]:
    """Positions of BP-tree nodes in an in-order traversal of the up-tree.
    Node key = (level, index-within-level), level 0 = leaves.  The in-order
    layout guarantees writes at any two nodes whose subtrees have > B leaves
    are >= B apart — zero up-pass block sharing above level log B."""
    assert n_leaves & (n_leaves - 1) == 0, "power of two"
    pos: dict[tuple[int, int], int] = {}
    counter = 0

    def rec(level: int, idx: int):
        nonlocal counter
        if level == 0:
            pos[(0, idx)] = counter
            counter += 1
            return
        rec(level - 1, 2 * idx)
        pos[(level, idx)] = counter
        counter += 1
        rec(level - 1, 2 * idx + 1)

    rec(int(math.log2(n_leaves)), 0)
    return pos
