"""The PWS planner: the paper's Priority Work-Stealing scheduler realized as a
*static* sharding planner for SPMD meshes.

Why this is PWS: for *balanced* HBP computations the paper proves the PWS
schedule is deterministic — steals happen in priority (= size, BFS) order and
at most p-1 tasks are stolen per priority level (Obs. 4.3).  On a lockstep
SPMD machine that schedule collapses to a static breadth-first partition of
the top log2(p) fork levels.  This module performs exactly that partition:

  * every parameter / activation / cache tensor is an HBP task tree whose
    fork levels are its axes (largest first = highest priority);
  * mesh axes are the "cores"; assigning an array axis to a mesh axis is the
    (deterministic, priority-ordered) steal of that fork level;
  * the paper's limited-access discipline (one writer per block) becomes the
    single-writer shard rule: gradients are reduce-scattered, not
    all-reduce-then-sliced; expert/KV slabs are padded ("gapped") to tile
    boundaries so no two shards share a tile.

The planner is the ONLY component that knows the mesh.  Models stay
resource-oblivious (paper §1: algorithms make no mention of p, M, B).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# TPU v5e hardware model used for tall-cache checks and tile quanta
VMEM_BYTES = 128 * 2**20 // 8  # ~16 MiB usable VMEM per core
LANE = 128
SUBLANE = 8


@dataclass(frozen=True)
class MeshAxes:
    """Names of the mesh axes by role."""

    dp: tuple[str, ...]  # data-parallel axes (outermost first), e.g. ("pod","data")
    fsdp: str  # axis that also shards parameters/optimizer (ZeRO)
    tp: str  # tensor/model-parallel axis

    @property
    def all_dp(self) -> tuple[str, ...]:
        return self.dp


def axes_for(mesh: Mesh) -> MeshAxes:
    names = mesh.axis_names
    if "pod" in names:
        return MeshAxes(dp=("pod", "data"), fsdp="data", tp="model")
    return MeshAxes(dp=("data",), fsdp="data", tp="model")


def tall_cache_ok(block_bytes: int = LANE * SUBLANE * 4) -> bool:
    """Paper's tall-cache condition M >= B^2 with M=VMEM, B=one native tile."""
    return VMEM_BYTES >= (block_bytes ** 2) ** 0.5 * block_bytes ** 0.5 or VMEM_BYTES >= block_bytes * 64


# ---------------------------------------------------------------------------
# parameter sharding rules (PWS priority order: biggest axes stolen first)
# ---------------------------------------------------------------------------
# rule: leaf-name -> PartitionSpec entries for the TRAILING dims of the leaf.
# Leading (layer-stack) dims are padded with None.

def _param_rules(ax: MeshAxes) -> dict[str, tuple]:
    fsdp, tp = ax.fsdp, ax.tp
    return {
        # embeddings: vocab over tp (vocab-parallel), d over fsdp
        "embed": (tp, fsdp),
        "lm_head": (tp, fsdp),
        # projections (in, out): column-parallel -> out over tp, in over fsdp
        "wq": (fsdp, tp), "wk": (fsdp, tp), "wv": (fsdp, tp),
        "w_gate": (fsdp, tp), "w_up": (fsdp, tp),
        "w_x": (fsdp, tp), "w_gate_branch": (fsdp, tp), "w_in": (fsdp, tp),
        "w_mlp_gate": (fsdp, tp), "w_mlp_up": (fsdp, tp),
        # row-parallel (in over tp, out over fsdp)
        "wo": (tp, fsdp), "w_down": (tp, fsdp), "w_out": (tp, fsdp),
        "w_mlp_down": (tp, fsdp),
        # biases follow the column dim
        "bq": (tp,), "bk": (tp,), "bv": (tp,),
        # router stays replicated over tp (it is tiny and every shard needs it)
        "router": (fsdp, None),
        # experts: expert axis over tp (EP), d over fsdp  — gapped slabs
        "e_gate": (tp, fsdp, None), "e_up": (tp, fsdp, None),
        "e_down": (tp, None, fsdp),
        # conv / recurrent params: width over tp
        "conv_w": (None, tp),
        "lru_a_gate": (None, None, None), "lru_i_gate": (None, None, None),
        "lru_a_param": (tp,),
        "A_log": (tp,), "dt_bias": (tp,), "D": (tp,), "gn": (tp,),
        # norms / scalar gates: replicated
        "ln": (None,), "ln1": (None,), "ln2": (None,), "ln3": (None,),
        "final_norm": (None,), "enc_norm": (None,),
        "q_norm": (None,), "k_norm": (None,),
        "xgate_attn": (), "xgate_ffn": (),
    }


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
    return ""


def plan_params(abstract_params: Any, mesh: Mesh, mode: str = "fsdp") -> Any:
    """PartitionSpec tree for a parameter pytree (leaf-name rules, leading
    layer-stack dims padded with None).  Dims that do not divide evenly by
    the mesh axis are left unsharded (the paper's balance condition: only
    balanced forks are stolen).

    mode="fsdp" (ZeRO-3): weights 2D-sharded (fsdp x tp) — per-layer weight
    all-gathers, minimum memory.  mode="zero1": weights tp-sharded only
    (replicated across data) — no per-layer gathers; use for models whose
    bf16 weights fit tp-sharded (the optimizer state stays fsdp-sharded by
    the caller)."""
    ax = axes_for(mesh)
    rules = _param_rules(ax)
    if mode == "zero1":
        rules = {
            name: tuple(None if a == ax.fsdp else a for a in rule)
            for name, rule in rules.items()
        }

    def spec_for(path, leaf):
        name = _leaf_name(path)
        ndim = len(leaf.shape)
        rule = rules.get(name)
        if rule is None:
            rule = (None,) * ndim
        rule = tuple(rule)
        pad = ndim - len(rule)
        entries = (None,) * pad + rule
        fixed = []
        for dim, axis in zip(leaf.shape, entries):
            if axis is None:
                fixed.append(None)
            elif dim % mesh.shape[axis] == 0:
                fixed.append(axis)
            else:
                fixed.append(None)  # unbalanced fork: do not steal
        return P(*fixed)

    return jax.tree_util.tree_map_with_path(spec_for, abstract_params)


def plan_batch(abstract_batch: Any, mesh: Mesh) -> Any:
    """Batch sharding: leading batch dim over all dp axes when divisible."""
    ax = axes_for(mesh)
    dp_size = 1
    for a in ax.dp:
        dp_size *= mesh.shape[a]

    def spec_for(path, leaf):
        shape = leaf.shape
        if len(shape) == 0:
            return P()
        if shape[0] % dp_size == 0 and shape[0] > 0:
            return P(ax.dp, *(None,) * (len(shape) - 1))
        # long-context single-batch: shard the sequence axis instead
        if len(shape) >= 2 and shape[1] % dp_size == 0:
            return P(None, ax.dp, *(None,) * (len(shape) - 2))
        return P(*(None,) * len(shape))

    return jax.tree_util.tree_map_with_path(spec_for, abstract_batch)


_KV_NAMES = {"k", "v", "xk", "xv", "img_k", "img_v"}


def plan_cache(abstract_cache: Any, mesh: Mesh) -> Any:
    """KV/state cache sharding.

    KV leaves are (..., b, S, kvh, hd): shard b over dp when divisible; shard
    kv-heads over tp when divisible, else shard S over tp (sequence
    parallelism — flash-decode style partial-softmax combine is emitted by
    GSPMD as all-reduce over tp).  For b == 1 (long-context), S is sharded
    over dp as well.  State leaves (ssm / lru / conv) shard their width/head
    axis over tp.
    """
    ax = axes_for(mesh)
    tp = ax.tp
    tp_size = mesh.shape[tp]
    dp_size = 1
    for a in ax.dp:
        dp_size *= mesh.shape[a]

    def spec_for(path, leaf):
        name = _leaf_name(path)
        shape = leaf.shape
        nd = len(shape)
        if name in _KV_NAMES:
            # trailing dims: (b, S, kvh, hd)
            entries: list = [None] * nd
            b_i, s_i, h_i = nd - 4, nd - 3, nd - 2
            if shape[b_i] % dp_size == 0:
                entries[b_i] = ax.dp
                if shape[h_i] % tp_size == 0:
                    entries[h_i] = tp
                elif shape[s_i] % tp_size == 0:
                    entries[s_i] = tp
            else:
                # batch=1 long context: sequence over (dp..., tp) as divisible
                if shape[s_i] % (dp_size * tp_size) == 0:
                    entries[s_i] = ax.dp + (tp,)
                elif shape[s_i] % dp_size == 0:
                    entries[s_i] = ax.dp
                elif shape[s_i] % tp_size == 0:
                    entries[s_i] = tp
            return P(*entries)
        if name in ("ssm",):  # (L, b, nh, hp, ds)
            entries = [None] * nd
            if shape[nd - 4] % dp_size == 0:
                entries[nd - 4] = ax.dp
            if shape[nd - 3] % tp_size == 0:
                entries[nd - 3] = tp
            return P(*entries)
        if name.startswith("lru"):  # (n, b, w)
            entries = [None] * nd
            if shape[nd - 2] % dp_size == 0:
                entries[nd - 2] = ax.dp
            if shape[nd - 1] % tp_size == 0:
                entries[nd - 1] = tp
            return P(*entries)
        if name.startswith("conv"):  # (L, b, k-1, w)
            entries = [None] * nd
            if shape[nd - 3] % dp_size == 0:
                entries[nd - 3] = ax.dp
            if shape[nd - 1] % tp_size == 0:
                entries[nd - 1] = tp
            return P(*entries)
        return P(*(None,) * nd)

    return jax.tree_util.tree_map_with_path(spec_for, abstract_cache)


def named(tree_of_specs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_of_specs,
        is_leaf=lambda x: isinstance(x, P),
    )
