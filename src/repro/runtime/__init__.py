from repro.runtime.elastic import (
    elastic_restore,
    replan_for_mesh,
    replan_params_for_mesh,
    respawn_mesh,
    serving_restore,
)
from repro.runtime.fault_tolerance import (
    FAULT_COUNTER_KEYS,
    FaultInjector,
    FaultPolicy,
    FaultTolerantRunner,
    InjectedFault,
    LaunchFailedError,
    StragglerMonitor,
    export_fault_counters,
    parse_fault_plan,
    parse_fleet_plan,
)
from repro.runtime.replica import Replica, health_score, spawn_replica

__all__ = [
    "FAULT_COUNTER_KEYS",
    "FaultInjector",
    "FaultPolicy",
    "FaultTolerantRunner",
    "InjectedFault",
    "LaunchFailedError",
    "Replica",
    "StragglerMonitor",
    "elastic_restore",
    "export_fault_counters",
    "health_score",
    "parse_fault_plan",
    "parse_fleet_plan",
    "replan_for_mesh",
    "replan_params_for_mesh",
    "respawn_mesh",
    "serving_restore",
    "spawn_replica",
]
