from repro.runtime.elastic import (
    elastic_restore,
    replan_for_mesh,
    replan_params_for_mesh,
    serving_restore,
)
from repro.runtime.fault_tolerance import (
    FaultInjector,
    FaultPolicy,
    FaultTolerantRunner,
    InjectedFault,
    LaunchFailedError,
    StragglerMonitor,
    parse_fault_plan,
)

__all__ = [
    "FaultInjector",
    "FaultPolicy",
    "FaultTolerantRunner",
    "InjectedFault",
    "LaunchFailedError",
    "StragglerMonitor",
    "parse_fault_plan",
    "elastic_restore",
    "replan_for_mesh",
    "replan_params_for_mesh",
    "serving_restore",
]
