from repro.runtime.fault_tolerance import FaultTolerantRunner, StragglerMonitor
from repro.runtime.elastic import replan_for_mesh

__all__ = ["FaultTolerantRunner", "StragglerMonitor", "replan_for_mesh"]
