"""Elastic scaling: re-plan shardings for a changed mesh.

The PWS planner is a deterministic function of the mesh (paper Obs. 4.3:
the steal schedule is determined by p) — so scaling from 512 to 256 chips
(or onto a degraded 2x15x16 slice) is: rebuild the mesh, re-run
``plan_params``/``plan_cache``, and device_put the checkpointed logical
arrays under the new shardings.  No per-tensor migration logic.

Two restart paths share the machinery: :func:`elastic_restore` rebuilds a
TRAIN state (params + optimizer) and :func:`serving_restore` a SERVING
replica (params only — decode caches are rebuilt empty and refilled by
request replay, so a replica restarted on a shrunken mesh serves logits
identical to the original; ``repro.launch.engine.Engine.restart`` is the
engine-level wrapper).
"""
from __future__ import annotations

from typing import Any

import jax

from repro.core import planner


def replan_for_mesh(abstract_state: Any, new_mesh) -> Any:
    """Shardings for a train state {params, opt_state} on a new mesh."""
    aparams = abstract_state["params"]
    pspec = planner.named(planner.plan_params(aparams, new_mesh), new_mesh)
    opt = abstract_state["opt_state"]
    ospec = {
        "step": jax.sharding.NamedSharding(new_mesh, jax.sharding.PartitionSpec()),
        "master": planner.named(planner.plan_params(opt["master"], new_mesh), new_mesh),
        "m": planner.named(planner.plan_params(opt["m"], new_mesh), new_mesh),
        "v": planner.named(planner.plan_params(opt["v"], new_mesh), new_mesh),
    }
    return {"params": pspec, "opt_state": ospec}


def elastic_restore(ckpt_manager, abstract_state: Any, new_mesh):
    """Restore the latest checkpoint resharded onto ``new_mesh``."""
    shardings = replan_for_mesh(abstract_state, new_mesh)
    step, state = ckpt_manager.restore_latest(abstract_state, shardings)
    return step, state, shardings


def replan_params_for_mesh(abstract_params: Any, new_mesh):
    """Shardings for a params-only (serving) state on a new mesh."""
    return planner.named(planner.plan_params(abstract_params, new_mesh),
                         new_mesh)


def respawn_mesh(prev_mesh, lost_devices: int = 0):
    """The mesh a replacement replica spins up on after its predecessor
    dies: the same device count minus ``lost_devices`` (a dead replica's
    hosts may be gone for good), re-planned through the debug-mesh
    factory so tensor-parallel stays as wide as the survivors allow.
    Shrinking to fewer devices is always legal — the PWS planner is
    deterministic in the mesh, so the respawned replica's logits match the
    original's whatever the shape (asserted by the router tests)."""
    from repro.launch.mesh import make_debug_mesh, mesh_device_count

    n = max(mesh_device_count(prev_mesh) - int(lost_devices), 1)
    return make_debug_mesh(n, tp=min(2, n))


def serving_restore(ckpt_manager, abstract_params: Any, new_mesh):
    """Restore the latest params checkpoint resharded onto ``new_mesh`` for
    a serving restart: no optimizer state, no cache (decode caches rebuild
    empty; in-flight requests replay through admission).  Accepts
    checkpoints saved as ``{"params": ...}`` (the train driver's layout).
    Returns ``(step, params, shardings)``."""
    shardings = replan_params_for_mesh(abstract_params, new_mesh)
    step, state = ckpt_manager.restore_latest({"params": abstract_params},
                                              {"params": shardings})
    return step, state["params"], shardings
