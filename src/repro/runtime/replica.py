"""Replica lifecycle for the fleet tier: spawn, health, death, respawn.

A *replica* is one serving :class:`~repro.launch.engine.Engine` plus the
fleet-side bookkeeping the router (``repro.launch.router``) needs: an id,
a live/dead/left state, how it was born (fresh init vs checkpoint-streamed
:meth:`Engine.restart`), and a health score folded from the engine's PR-9
fault counters.  The module is deliberately engine-agnostic at import time
(lazy imports) so ``repro.runtime`` keeps no top-level dependency on
``repro.launch`` — the same layering rule that keeps the simulator core
below the serving stack.

Health is signal-driven, not guessed: :func:`health_score` reads the
``faults`` slice of ``Engine.stats()`` (``retries``, ``stragglers``,
``degradations``, ``degraded_iters`` — the counters the degradation window
already maintains) and maps it into ``[0, 1]``.  The router sheds load
away from replicas under ``SHED_THRESHOLD``; the weights are sized so that
isolated stragglers never cross it (placement stays deterministic under
benign jitter) while a degradation event or a retry burst does.
"""
from __future__ import annotations

import logging
from dataclasses import dataclass, field
from typing import Optional

log = logging.getLogger("repro.replica")

# health = 1 - sum(weight * counter), clamped to [0, 1].  degradations are
# the strongest signal (the engine already judged the fault rate unhealthy);
# retries mean launches are failing; stragglers/degraded_iters are mild
# per-event evidence so routine jitter stays comfortably above the shed bar.
HEALTH_WEIGHTS = {
    "retries": 0.15,
    "stragglers": 0.02,
    "degradations": 0.30,
    "degraded_iters": 0.01,
}
SHED_THRESHOLD = 0.5


def health_score(stats: dict) -> float:
    """Fold an ``Engine.stats()`` dict into one load-shedding signal in
    ``[0, 1]`` (1 = healthy).  Reads only the structured ``faults`` slice —
    no private engine attributes."""
    faults = stats.get("faults", {})
    score = 1.0
    for key, w in HEALTH_WEIGHTS.items():
        score -= w * float(faults.get(key, 0))
    return max(0.0, min(1.0, score))


@dataclass
class Replica:
    """One engine plus its fleet-side identity and state."""

    rid: int
    engine: object
    state: str = "live"            # live | dead | left
    spawned_from: str = "init"     # init | checkpoint
    health: float = 1.0
    stats: dict = field(default_factory=dict)

    def refresh_health(self) -> float:
        """Re-read the engine's stats and fold them into ``health``."""
        self.stats = self.engine.stats()
        self.health = health_score(self.stats)
        return self.health

    def shed(self) -> bool:
        """True when the router should route new work away from here."""
        return self.health < SHED_THRESHOLD

    def provenance(self) -> dict:
        """This replica's row in the router telemetry: identity, mesh,
        kernel policy + autotune table provenance (per-replica — replicas
        on different device kinds replay different tuned tables), and the
        live health/fault picture."""
        from repro.kernels import autotune as kernel_autotune
        from repro.kernels import policy as kernel_policy

        return {
            "rid": self.rid,
            "state": self.state,
            "spawned_from": self.spawned_from,
            "mesh": dict(self.engine.mesh.shape),
            "policy": kernel_policy.current().describe(),
            "autotune": kernel_autotune.provenance(),
            "health": self.health,
            "faults": dict(self.stats.get("faults", {})),
        }


def spawn_replica(rid: int, cfg, mesh, ckpt_dir=None, **engine_kw) -> Replica:
    """Bring one replica up.  With ``ckpt_dir`` the spin-up is
    checkpoint-streamed — params restore through
    ``elastic.serving_restore`` onto ``mesh`` via :meth:`Engine.restart`,
    so every replica of a fleet serves logits identical to the replica
    whose params were checkpointed.  Without it the engine initializes
    fresh (the fleet's replica 0, whose params seed the checkpoint)."""
    from repro.launch.engine import Engine

    if ckpt_dir is None:
        rep = Replica(rid, Engine(cfg, mesh, **engine_kw))
    else:
        rep = Replica(rid, Engine.restart(cfg, mesh, ckpt_dir, **engine_kw),
                      spawned_from="checkpoint")
    log.info("replica %d up (%s, mesh %s)", rid, rep.spawned_from,
             dict(mesh.shape))
    return rep
