"""Fault tolerance: fault injection, bounded retry, straggler detection.

At 1000+ node scale the failure model is: (a) hard node loss -> job restart
from the latest checkpoint on a (possibly re-sized) mesh; (b) transient step
failure (preemption notice, ECC retry, link flap) -> bounded in-place retry;
(c) stragglers -> detected by per-step wall-time z-scores, mitigated by
checkpoint-and-replan (the PWS planner is deterministic in p, so dropping to
a smaller healthy mesh is a pure re-plan + elastic reshard — no manual
resharding logic).

The serving engine (``repro.launch.engine``) maps the same taxonomy onto
launches instead of train steps: (a) a launch that exhausts its retries
raises :class:`LaunchFailedError` for a job-level restart, (b) a transient
launch fault retries under :class:`FaultPolicy`'s bounded backoff, and
(c) straggler launches are flagged by the same :class:`StragglerMonitor`
z-scores and feed the engine's graceful-degradation window.

Everything here is policy-only and model-free: the runner wraps any step
callable, and :class:`FaultInjector` drives the SAME injected-fault plans
through tests, the CI smoke arm, and the bench recovery arm.  Faults fire
deterministically from a declarative plan; the one sanctioned source of
nondeterminism is the seeded retry-backoff jitter (:class:`FaultPolicy` —
the RWS companion analysis' randomized-stealing model), which perturbs
*wall time* only, never the recovered output.
"""
from __future__ import annotations

import logging
import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import numpy as np

log = logging.getLogger(__name__)

FAULT_PLAN_ENV = "REPRO_FAULTS"

# The engine's fault-telemetry counter names, in one place so every consumer
# (Engine.stats(), the router's health score, benches) slices the same keys
# out of the scheduler's counter dict instead of hard-coding its layout.
FAULT_COUNTER_KEYS = (
    "retries", "faults_injected", "slots_poisoned", "snapshots_taken",
    "snapshot_restores", "stragglers", "degradations", "degraded_iters",
)


def export_fault_counters(counters: dict) -> dict:
    """The fault-tolerance slice of an engine's telemetry counters (missing
    keys read as 0 — a counter dict from an older engine stays valid)."""
    return {k: counters.get(k, 0) for k in FAULT_COUNTER_KEYS}


def parse_fleet_plan(plan: str, n_replicas: int) -> list[str]:
    """Split a fleet fault plan into per-replica engine plans.  The fleet
    grammar is ``plan[|plan...]`` — ``|``-separated positional per-replica
    plans (position = replica id, missing tails empty), e.g.
    ``|decode@4=raise:99`` faults replica 1 only.  A plan with no ``|``
    applies to EVERY replica, matching single-engine semantics.  Each piece
    is validated through :func:`parse_fault_plan`."""
    if "|" in plan:
        pieces = [p.strip() for p in plan.split("|")]
        if len(pieces) > n_replicas:
            raise ValueError(
                f"fleet fault plan names {len(pieces)} replicas but the "
                f"router has {n_replicas}")
        pieces += [""] * (n_replicas - len(pieces))
    else:
        pieces = [plan] * n_replicas
    for piece in pieces:
        parse_fault_plan(piece)
    return pieces


class InjectedFault(RuntimeError):
    """A fault raised by :class:`FaultInjector` (distinguishable from real
    failures in logs; handled identically by the retry machinery)."""


class LaunchFailedError(RuntimeError):
    """A launch exhausted its bounded retries — the serving analogue of a
    hard step failure, escalated for job-level restart."""

    def __init__(self, kind: str, ordinal: int, attempts: int):
        super().__init__(
            f"{kind} launch {ordinal} failed after {attempts} attempt(s)")
        self.kind = kind
        self.ordinal = ordinal
        self.attempts = attempts


@dataclass
class FaultSpec:
    """One parsed fault-plan entry.

    ``kind``     ``decode`` | ``prefill`` (``index`` = per-run launch
                 ordinal) or ``slot`` (``index`` = engine slot id).
    ``action``   ``raise`` (fail the launch; ``arg`` = consecutive attempts
                 to fail, default 1), ``delay`` (sleep before the launch —
                 a straggler; ``arg`` = seconds, default 0.05), or
                 ``nan_logits`` (poison the slot's logits; ``arg`` = fire on
                 the n-th decode launch in which the slot is decoding,
                 default 1).
    """

    kind: str
    index: int
    action: str
    arg: float
    remaining: float = field(default=0.0)

    def __post_init__(self):
        # 'raise' burns one count per failed attempt; the others fire once
        # after 'arg' eligible launches (delay is immediate: count 1)
        self.remaining = self.arg if self.action == "raise" else (
            self.arg if self.action == "nan_logits" else 1)


_KINDS = ("decode", "prefill", "slot")
_ACTIONS = ("raise", "delay", "nan_logits")
_DEFAULT_ARG = {"raise": 1, "delay": 0.05, "nan_logits": 1}


def parse_fault_plan(plan: str) -> list[FaultSpec]:
    """Parse the declarative grammar
    ``kind@index=action[:arg][,kind@index=action[:arg]...]``, e.g.
    ``decode@12=raise,prefill@3=delay:0.2,slot@2=nan_logits``."""
    specs: list[FaultSpec] = []
    for raw in filter(None, (e.strip() for e in plan.split(","))):
        try:
            target, action = raw.split("=", 1)
            kind, index = target.split("@", 1)
            arg = None
            if ":" in action:
                action, arg_s = action.split(":", 1)
                arg = float(arg_s)
        except ValueError as e:
            raise ValueError(f"malformed fault-plan entry {raw!r} "
                             "(want kind@index=action[:arg])") from e
        if kind not in _KINDS:
            raise ValueError(f"unknown fault kind {kind!r} in {raw!r} "
                             f"(want one of {_KINDS})")
        if action not in _ACTIONS:
            raise ValueError(f"unknown fault action {action!r} in {raw!r} "
                             f"(want one of {_ACTIONS})")
        if action == "nan_logits" and kind != "slot":
            raise ValueError(f"{raw!r}: nan_logits targets a slot")
        if action in ("raise", "delay") and kind == "slot":
            raise ValueError(f"{raw!r}: {action} targets a launch "
                             "(decode/prefill)")
        specs.append(FaultSpec(kind, int(index), action,
                               _DEFAULT_ARG[action] if arg is None else arg))
    return specs


class FaultInjector:
    """Deterministic, plan-driven fault source.

    The plan (see :func:`parse_fault_plan`; ``REPRO_FAULTS`` env) names
    exactly which launches fail, which straggle, and which slot's logits go
    non-finite — so a faulted run is reproducible end to end and its
    recovered output can be asserted *token-identical* to the clean run.
    The seed jitters only the injected delay's duration (never whether or
    where a fault fires).
    """

    def __init__(self, plan: str = "", seed: int = 0):
        self.plan = plan
        self.specs = parse_fault_plan(plan)
        self.rng = np.random.default_rng(seed)
        self.counters = {"faults_injected": 0}

    @classmethod
    def from_env(cls, seed: int = 0) -> "FaultInjector":
        """An injector for the ``REPRO_FAULTS`` plan (empty plan = no-op)."""
        return cls(os.environ.get(FAULT_PLAN_ENV, ""), seed=seed)

    def __bool__(self) -> bool:
        return bool(self.specs)

    def describe(self) -> str:
        return self.plan or "none"

    def before_launch(self, kind: str, ordinal: int) -> None:
        """Fire any ``raise``/``delay`` fault planned for this launch.
        Raises :class:`InjectedFault` BEFORE the launch commits (donated
        buffers untouched), so a bounded retry of the same arguments is
        sound; a ``delay`` sleeps in the launch's timed window so the
        straggler watchdog sees it."""
        for spec in self.specs:
            if (spec.kind != kind or spec.index != ordinal
                    or spec.remaining <= 0):
                continue
            if spec.action == "raise":
                spec.remaining -= 1
                self.counters["faults_injected"] += 1
                raise InjectedFault(f"injected: {kind} launch {ordinal}")
            if spec.action == "delay":
                spec.remaining -= 1
                self.counters["faults_injected"] += 1
                # seeded jitter perturbs duration only — never the outcome
                time.sleep(spec.arg * (1.0 + 0.1 * self.rng.random()))

    def poison_rows(self, decoding_slots) -> list[int]:
        """Slot ids whose logits must go non-finite on THIS decode launch:
        each ``slot@i=nan_logits:n`` entry counts down one per decode launch
        in which slot ``i`` is decoding and fires on the n-th."""
        out = []
        for spec in self.specs:
            if (spec.kind != "slot" or spec.action != "nan_logits"
                    or spec.remaining <= 0 or spec.index not in decoding_slots):
                continue
            spec.remaining -= 1
            if spec.remaining <= 0:
                self.counters["faults_injected"] += 1
                out.append(spec.index)
        return out


@dataclass(frozen=True)
class FaultPolicy:
    """Bounded-retry policy: up to ``max_retries`` in-place retries with
    exponential backoff and seeded jitter.  The jitter is the RWS-style
    randomized arm — it decorrelates retry storms across replicas without
    touching the recovered output (launches are pure functions of their
    arguments)."""

    max_retries: int = 2
    backoff_s: float = 0.005
    backoff_mult: float = 2.0
    jitter: float = 0.5
    seed: int = 0

    def make_rng(self) -> np.random.Generator:
        return np.random.default_rng(self.seed)

    def backoff(self, attempt: int, rng: np.random.Generator) -> float:
        """Sleep before retry ``attempt`` (0-based): exponential base times
        a seeded multiplicative jitter in [1, 1 + jitter)."""
        base = self.backoff_s * (self.backoff_mult ** attempt)
        return base * (1.0 + self.jitter * float(rng.random()))


@dataclass
class StragglerMonitor:
    """Rolling per-step time stats; flags steps slower than mean + k*std.
    Flagged samples are EXCLUDED from the rolling window — a genuine
    straggler must not inflate the std and mask the next one.  On real
    pods, per-host step times arrive via the coordination service; here the
    same math runs on the local step series."""

    window: int = 50
    k_sigma: float = 3.0
    min_samples: int = 10
    times: list[float] = field(default_factory=list)
    flagged: int = 0

    def observe(self, dt: float) -> bool:
        """Returns True if this step is a straggler."""
        ts = self.times
        if len(ts) >= self.min_samples:
            mean = sum(ts) / len(ts)
            var = sum((t - mean) ** 2 for t in ts) / len(ts)
            if dt > mean + self.k_sigma * max(var ** 0.5, 1e-9):
                self.flagged += 1
                return True  # outlier: keep it OUT of the window stats
        ts.append(dt)
        if len(ts) > self.window:
            ts.pop(0)
        return False


class FaultTolerantRunner:
    """Wraps a training loop step with retry + periodic checkpointing.

    Usage::
        runner = FaultTolerantRunner(ckpt_manager, save_every=100)
        state, start = runner.restore_or(state_init, shardings)
        for step in range(start, total):
            state = runner.run_step(step, lambda: train_step(state, batch))
    """

    def __init__(self, ckpt_manager, *, save_every: int = 100,
                 max_retries: int = 2, mesh_shape: Optional[dict] = None):
        self.ckpt = ckpt_manager
        self.save_every = save_every
        self.max_retries = max_retries
        self.mesh_shape = mesh_shape or {}
        self.monitor = StragglerMonitor()
        self.retries = 0

    def restore_or(self, state_init: Any, shardings: Any = None) -> tuple[Any, int]:
        try:
            step, state = self.ckpt.restore_latest(state_init, shardings)
            log.info("restored checkpoint at step %d", step)
            return state, step + 1
        except FileNotFoundError:
            return state_init, 0

    def run_step(self, step: int, step_fn: Callable[[], Any]) -> Any:
        """Execute one step with bounded retry; checkpoint on schedule.
        ``step_fn`` closes over whatever state it needs."""
        last_exc: Optional[BaseException] = None
        for attempt in range(self.max_retries + 1):
            t0 = time.time()
            try:
                new_state = step_fn()
                dt = time.time() - t0
                if self.monitor.observe(dt):
                    log.warning("straggler step %d: %.3fs", step, dt)
                if self.save_every and (step + 1) % self.save_every == 0:
                    self.ckpt.save_async(step, new_state, self.mesh_shape)
                return new_state
            except Exception as e:  # noqa: BLE001 — deliberate: retry any step fault
                last_exc = e
                self.retries += 1
                log.warning("step %d attempt %d failed: %r", step, attempt, e)
        # out of retries: persist what we have and re-raise for job-level restart
        self.ckpt.wait()
        raise RuntimeError(f"step {step} failed after {self.max_retries} retries") from last_exc
