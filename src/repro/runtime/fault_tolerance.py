"""Fault tolerance: checkpoint/restart, step retry, straggler detection.

At 1000+ node scale the failure model is: (a) hard node loss -> job restart
from the latest checkpoint on a (possibly re-sized) mesh; (b) transient step
failure (preemption notice, ECC retry, link flap) -> bounded in-place retry;
(c) stragglers -> detected by per-step wall-time z-scores, mitigated by
checkpoint-and-replan (the PWS planner is deterministic in p, so dropping to
a smaller healthy mesh is a pure re-plan + elastic reshard — no manual
resharding logic).

The runner is deliberately policy-only: it wraps any step callable, so the
same machinery drives tests (with injected failures) and real jobs.
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

log = logging.getLogger(__name__)


@dataclass
class StragglerMonitor:
    """Rolling per-step time stats; flags steps slower than mean + k*std.
    On real pods, per-host step times arrive via the coordination service;
    here the same math runs on the local step series."""

    window: int = 50
    k_sigma: float = 3.0
    min_samples: int = 10
    times: list[float] = field(default_factory=list)
    flagged: int = 0

    def observe(self, dt: float) -> bool:
        """Returns True if this step is a straggler."""
        ts = self.times
        is_straggler = False
        if len(ts) >= self.min_samples:
            mean = sum(ts) / len(ts)
            var = sum((t - mean) ** 2 for t in ts) / len(ts)
            if dt > mean + self.k_sigma * max(var ** 0.5, 1e-9):
                is_straggler = True
                self.flagged += 1
        ts.append(dt)
        if len(ts) > self.window:
            ts.pop(0)
        return is_straggler


class FaultTolerantRunner:
    """Wraps a training loop step with retry + periodic checkpointing.

    Usage::
        runner = FaultTolerantRunner(ckpt_manager, save_every=100)
        state, start = runner.restore_or(state_init, shardings)
        for step in range(start, total):
            state = runner.run_step(step, lambda: train_step(state, batch))
    """

    def __init__(self, ckpt_manager, *, save_every: int = 100,
                 max_retries: int = 2, mesh_shape: Optional[dict] = None):
        self.ckpt = ckpt_manager
        self.save_every = save_every
        self.max_retries = max_retries
        self.mesh_shape = mesh_shape or {}
        self.monitor = StragglerMonitor()
        self.retries = 0

    def restore_or(self, state_init: Any, shardings: Any = None) -> tuple[Any, int]:
        try:
            step, state = self.ckpt.restore_latest(state_init, shardings)
            log.info("restored checkpoint at step %d", step)
            return state, step + 1
        except FileNotFoundError:
            return state_init, 0

    def run_step(self, step: int, state: Any, step_fn: Callable[[], Any]) -> Any:
        """Execute one step with bounded retry; checkpoint on schedule."""
        last_exc: Optional[BaseException] = None
        for attempt in range(self.max_retries + 1):
            t0 = time.time()
            try:
                new_state = step_fn()
                dt = time.time() - t0
                if self.monitor.observe(dt):
                    log.warning("straggler step %d: %.3fs", step, dt)
                if self.save_every and (step + 1) % self.save_every == 0:
                    self.ckpt.save_async(step, new_state, self.mesh_shape)
                return new_state
            except Exception as e:  # noqa: BLE001 — deliberate: retry any step fault
                last_exc = e
                self.retries += 1
                log.warning("step %d attempt %d failed: %r", step, attempt, e)
        # out of retries: persist what we have and re-raise for job-level restart
        self.ckpt.wait()
        raise RuntimeError(f"step {step} failed after {self.max_retries} retries") from last_exc
