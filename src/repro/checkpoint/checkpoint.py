"""Sharded, checksummed, async checkpointing with elastic resharding.

Design (scaled-down tensorstore): one .npy file per pytree leaf + a JSON
manifest carrying the tree structure, step, per-leaf SHA-256 checksums and
the mesh the state was saved under.  Restore validates checksums and — for
elastic restarts — RESHARDS onto a different mesh simply by loading the full
logical arrays and re-applying the PWS planner's shardings for the new mesh
(the PWS schedule is a pure function of p, Obs. 4.3, so re-planning after a
topology change is deterministic).

Fault-tolerance contract:
  * atomic: writes go to ``step_N.tmp/`` then rename — a crash mid-save
    never corrupts the latest complete checkpoint;
  * async: ``save_async`` snapshots to host memory then writes in a
    background thread (training continues);
  * retention: keep the last K checkpoints.
"""
from __future__ import annotations

import hashlib
import json
import shutil
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_names(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        name = "/".join(
            str(p.key) if isinstance(p, jax.tree_util.DictKey) else str(getattr(p, "idx", p))
            for p in path
        )
        names.append(name)
        leaves.append(leaf)
    return names, leaves, treedef


def save_checkpoint(directory: str | Path, step: int, state: Any,
                    mesh_shape: Optional[dict] = None, keep: int = 3) -> Path:
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    tmp = directory / f"step_{step}.tmp"
    final = directory / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    names, leaves, _ = _flatten_with_names(state)
    manifest = {"step": step, "mesh_shape": mesh_shape or {}, "leaves": []}
    for i, (name, leaf) in enumerate(zip(names, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        logical_dtype = str(arr.dtype)
        if arr.dtype.kind == "V" or logical_dtype == "bfloat16":
            # np.save cannot represent ml_dtypes (bf16 etc.): store the raw
            # bits as uint16 and record the logical dtype in the manifest
            logical_dtype = "bfloat16"
            arr = arr.view(np.uint16)
        fn = f"leaf_{i:05d}.npy"
        np.save(tmp / fn, arr)
        digest = hashlib.sha256((tmp / fn).read_bytes()).hexdigest()
        manifest["leaves"].append(
            {"name": name, "file": fn, "dtype": logical_dtype,
             "shape": list(arr.shape), "sha256": digest}
        )
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic publish

    # retention
    steps = sorted(
        (int(p.name.split("_")[1]) for p in directory.glob("step_*")
         if not p.name.endswith(".tmp")),
    )
    for old in steps[:-keep]:
        shutil.rmtree(directory / f"step_{old}", ignore_errors=True)
    return final


def latest_step(directory: str | Path) -> Optional[int]:
    directory = Path(directory)
    steps = [int(p.name.split("_")[1]) for p in directory.glob("step_*")
             if not p.name.endswith(".tmp")]
    return max(steps) if steps else None


def load_checkpoint(directory: str | Path, state_like: Any,
                    step: Optional[int] = None, shardings: Any = None) -> tuple[int, Any]:
    """Restore into the structure of ``state_like``.  ``shardings`` (a pytree
    of NamedSharding for the CURRENT mesh) enables elastic resharding: the
    loaded logical arrays are placed per the new plan."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    d = directory / f"step_{step}"
    manifest = json.loads((d / "manifest.json").read_text())

    names, leaves, treedef = _flatten_with_names(state_like)
    by_name = {m["name"]: m for m in manifest["leaves"]}
    out = []
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(leaves))
    for name, like, sh in zip(names, leaves, shard_leaves):
        m = by_name[name]
        raw = (d / m["file"]).read_bytes()
        if hashlib.sha256(raw).hexdigest() != m["sha256"]:
            raise IOError(f"checksum mismatch for {name}")
        arr = np.load(d / m["file"])
        if m["dtype"] == "bfloat16":
            import ml_dtypes

            arr = arr.view(ml_dtypes.bfloat16)
        if list(arr.shape) != list(like.shape):
            raise ValueError(f"shape mismatch {name}: {arr.shape} vs {like.shape}")
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr, dtype=like.dtype))
    return manifest["step"], jax.tree.unflatten(treedef, out)


class CheckpointManager:
    """Async save + restore-latest."""

    def __init__(self, directory: str | Path, keep: int = 3):
        self.directory = Path(directory)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save_async(self, step: int, state: Any, mesh_shape: Optional[dict] = None):
        self.wait()
        # snapshot to host first (cheap for CPU backend; on TPU this is the
        # device->host copy that must complete before training mutates state)
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)

        def work():
            try:
                save_checkpoint(self.directory, step, host_state, mesh_shape, self.keep)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def restore_latest(self, state_like: Any, shardings: Any = None):
        return load_checkpoint(self.directory, state_like, shardings=shardings)
