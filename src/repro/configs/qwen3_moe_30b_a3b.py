"""qwen3-moe-30b-a3b — MoE, 128 experts top-8, GQA (kv=4).
[hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.configs.base import ModelConfig, reduced, register

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=768,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    n_experts=128,
    experts_per_token=8,
    expert_d_ff=768,
    tie_embeddings=False,
)

SMOKE = reduced(
    CONFIG,
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=64,
    vocab_size=256,
    n_experts=8,
    experts_per_token=2,
    expert_d_ff=64,
)

register(CONFIG, SMOKE)
