"""olmoe-1b-7b — MoE, 64 experts top-8, MHA (kv=16).  [arXiv:2409.02060; hf]"""
from repro.configs.base import ModelConfig, reduced, register

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    qk_norm=True,  # OLMoE uses QK-norm
    rope_theta=10_000.0,
    n_experts=64,
    experts_per_token=8,
    expert_d_ff=1024,
    tie_embeddings=False,
)

SMOKE = reduced(
    CONFIG,
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=64,
    vocab_size=256,
    n_experts=8,
    experts_per_token=2,
    expert_d_ff=64,
)

register(CONFIG, SMOKE)
