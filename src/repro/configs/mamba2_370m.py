"""mamba2-370m — attention-free SSM with SSD (state-space duality).
[arXiv:2405.21060; unverified]

The SSD chunked recurrence is literally the paper's two-pass BP prefix-scan
shape: per-chunk local reductions (down-pass) + cross-chunk state scan
(up-pass / second pass).  See repro.kernels.bp_scan.
"""
from repro.configs.base import ModelConfig, reduced, register

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    tie_embeddings=True,
)

SMOKE = reduced(
    CONFIG,
    n_layers=2,
    d_model=64,
    vocab_size=256,
    ssm_state=16,
    ssm_head_dim=16,
    ssm_chunk=8,
)

register(CONFIG, SMOKE)
