"""recurrentgemma-2b — hybrid: RG-LRU recurrent blocks + local attention,
pattern (rec, rec, attn) => 1:2 attn:recurrent.  [arXiv:2402.19427; hf]"""
from repro.configs.base import ModelConfig, reduced, register

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab_size=256000,
    head_dim=256,
    rope_theta=10_000.0,
    sliding_window=2048,
    block_pattern=("rglru", "rglru", "attn"),
    lru_width=2560,
    conv1d_width=4,
    tie_embeddings=True,
    scale_embeddings=True,
)

SMOKE = reduced(
    CONFIG,
    n_layers=3,
    d_model=64,
    n_heads=2,
    n_kv_heads=1,
    head_dim=32,
    d_ff=128,
    vocab_size=256,
    sliding_window=8,
    lru_width=64,
)

register(CONFIG, SMOKE)
