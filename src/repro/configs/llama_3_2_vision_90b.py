"""llama-3.2-vision-90b — VLM backbone: 100 layers, every 5th layer is a
cross-attention (image) layer.  Vision frontend is a STUB: input_specs()
provides precomputed patch embeddings.  [hf:meta-llama/Llama-3.2-11B-Vision;
unverified]"""
from repro.configs.base import ModelConfig, reduced, register

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    head_dim=128,
    rope_theta=500_000.0,
    cross_attn_every=5,
    n_image_tokens=1601,  # 1 tile x (40x40 patches + 1 cls)
    tie_embeddings=False,
)

SMOKE = reduced(
    CONFIG,
    n_layers=5,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    cross_attn_every=5,
    n_image_tokens=17,
)

register(CONFIG, SMOKE)
