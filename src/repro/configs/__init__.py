from repro.configs.base import (
    SHAPES,
    ModelConfig,
    ShapeConfig,
    get_config,
    get_smoke_config,
    list_archs,
    reduced,
)

__all__ = [
    "SHAPES",
    "ModelConfig",
    "ShapeConfig",
    "get_config",
    "get_smoke_config",
    "list_archs",
    "reduced",
]
