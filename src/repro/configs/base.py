"""Model/architecture configuration for the repro framework.

Every assigned architecture is a frozen ``ModelConfig``.  The config is
resource-oblivious in the paper's sense: nothing in it references the mesh,
cache sizes, or block sizes — those belong to the PWS planner
(``repro.core.planner``) and the launchers.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional

import jax.numpy as jnp


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned (input-shape) cell: seq_len x global_batch + step kind."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


# The four assigned LM shapes (identical across the 10 architectures).
SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description.  Fields default to 'absent'."""

    name: str
    family: str  # dense | moe | vlm | hybrid | ssm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None  # explicit head dim (Qwen3, Gemma3, ...)
    qkv_bias: bool = False  # Qwen2.5
    qk_norm: bool = False  # Qwen3 family
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    scale_embeddings: bool = False  # Gemma-style sqrt(d) embedding scale

    # local/global attention interleaving (Gemma3: 5 local : 1 global)
    sliding_window: Optional[int] = None
    global_every: Optional[int] = None  # every k-th layer is global

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    expert_d_ff: int = 0
    capacity_factor: float = 1.25  # gapped-capacity padding (paper: gapping)
    router_aux_weight: float = 0.01

    # VLM (cross-attention image layers; vision frontend is a stub)
    cross_attn_every: Optional[int] = None
    n_image_tokens: int = 0

    # hybrid (RecurrentGemma): repeating block pattern, e.g. ("rglru","rglru","attn")
    block_pattern: tuple[str, ...] = ()
    lru_width: int = 0
    conv1d_width: int = 4

    # SSM (Mamba-2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    # SSD chunk (BP leaf); None = derived by the kernel planner
    ssm_chunk: Optional[int] = 256

    # encoder-decoder (Seamless)
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    # encoder input length as a fraction of decoder seq_len (audio frames stub)
    encoder_len_ratio: float = 0.25

    # activation dtype
    dtype: str = "bfloat16"

    # -- derived ----------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // max(self.n_heads, 1)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim_

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim_

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """True if attention cost is sub-quadratic in context (SSM / hybrid /
        mostly-sliding-window).  Pure full-attention archs skip long_500k."""
        if self.family in ("ssm", "hybrid"):
            return True
        # mostly-local attention (Gemma3 5:1) bounds the full-attention layers
        return self.sliding_window is not None and (self.global_every or 0) > 1

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_n_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytic parameter count N (used for MODEL_FLOPS = 6*N*D)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim_
        emb = v * d * (1 if self.tie_embeddings else 2)

        def attn_params() -> int:
            p = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            if self.qkv_bias:
                p += self.q_dim + 2 * self.kv_dim
            if self.qk_norm:
                p += 2 * hd
            return p

        def mlp_params(f: int) -> int:
            return 3 * d * f  # gated (SwiGLU-style): up, gate, down

        def norm_params() -> int:
            return 2 * d

        total = emb + d  # final norm
        if self.family in ("dense", "vlm"):
            per_layer = attn_params() + mlp_params(ff) + norm_params()
            total += self.n_layers * per_layer
            if self.cross_attn_every:
                n_cross = self.n_layers // self.cross_attn_every
                total += n_cross * (attn_params() + norm_params())
        elif self.family == "moe":
            per_layer = attn_params() + norm_params()
            per_layer += d * self.n_experts  # router
            per_layer += self.n_experts * 3 * d * self.expert_d_ff
            total += self.n_layers * per_layer
        elif self.family == "hybrid":
            w = self.lru_width or d
            n_attn = sum(1 for b in self._layer_kinds() if b == "attn")
            n_rec = self.n_layers - n_attn
            rec = d * w * 2 + w * self.conv1d_width + 2 * w + w * d  # x/gate proj, conv, lru gates, out
            total += n_rec * (rec + norm_params()) + n_attn * (attn_params() + norm_params())
            total += self.n_layers * mlp_params(ff)
        elif self.family == "ssm":
            di, ds = self.ssm_d_inner, self.ssm_state
            nh = self.ssm_n_heads
            per_layer = d * (2 * di + 2 * ds + nh)  # in_proj(zx) + B,C proj + dt
            per_layer += di * self.conv1d_width + nh + nh  # conv, A_log, D
            per_layer += di * d + norm_params()
            total += self.n_layers * per_layer
        elif self.family == "audio":
            per_enc = attn_params() + mlp_params(ff) + norm_params()
            per_dec = 2 * attn_params() + mlp_params(ff) + norm_params()
            total += self.encoder_layers * per_enc + self.n_layers * per_dec
        return total

    def active_param_count(self) -> int:
        """Active params per token (== param_count for dense)."""
        if self.family != "moe":
            return self.param_count()
        inactive = self.n_layers * (self.n_experts - self.experts_per_token) * 3 * self.d_model * self.expert_d_ff
        return self.param_count() - inactive

    def _layer_kinds(self) -> list[str]:
        """Per-layer kind sequence for pattern archs (hybrid)."""
        if not self.block_pattern:
            return ["attn"] * self.n_layers
        kinds: list[str] = []
        while len(kinds) < self.n_layers:
            kinds.extend(self.block_pattern)
        return kinds[: self.n_layers]


_REGISTRY: dict[str, "ModelConfig"] = {}
_SMOKE_REGISTRY: dict[str, "ModelConfig"] = {}


def register(cfg: ModelConfig, smoke: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    _SMOKE_REGISTRY[cfg.name] = smoke
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def get_smoke_config(name: str) -> ModelConfig:
    _ensure_loaded()
    return _SMOKE_REGISTRY[name]


def list_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    return dataclasses.replace(cfg, **overrides)


_LOADED = False


def _ensure_loaded() -> None:
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    from repro.configs import (  # noqa: F401
        qwen2_5_14b,
        gemma3_1b,
        qwen3_32b,
        qwen3_1_7b,
        olmoe_1b_7b,
        qwen3_moe_30b_a3b,
        llama_3_2_vision_90b,
        recurrentgemma_2b,
        mamba2_370m,
        seamless_m4t_large_v2,
    )
