"""gemma3-1b — dense, GQA (kv=1), 5:1 local:global sliding window, 128k ctx.
[hf:google/gemma-3-1b-pt; unverified]"""
from repro.configs.base import ModelConfig, reduced, register

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    d_ff=6912,
    vocab_size=262144,
    head_dim=256,
    rope_theta=1_000_000.0,
    sliding_window=512,
    global_every=6,  # layers 5, 11, 17, 23 are global -> 5:1 local:global
    tie_embeddings=True,
    scale_embeddings=True,
)

SMOKE = reduced(
    CONFIG,
    n_layers=6,
    d_model=64,
    n_heads=2,
    n_kv_heads=1,
    head_dim=32,
    d_ff=128,
    vocab_size=256,
    sliding_window=8,
    global_every=3,
)

register(CONFIG, SMOKE)
