"""seamless-m4t-large-v2 — encoder-decoder multimodal (audio) backbone.
The speech frontend is a STUB: input_specs() provides precomputed frame
embeddings of shape (batch, enc_len, d_model).  [arXiv:2308.11596; hf]"""
from repro.configs.base import ModelConfig, reduced, register

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,  # decoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    rope_theta=10_000.0,
    is_encoder_decoder=True,
    encoder_layers=24,
    encoder_len_ratio=0.25,
    tie_embeddings=False,
)

SMOKE = reduced(
    CONFIG,
    n_layers=2,
    encoder_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
)

register(CONFIG, SMOKE)
