"""qwen3-32b — dense, GQA (kv=8), qk_norm.  [hf:Qwen/Qwen3-8B; hf]"""
from repro.configs.base import ModelConfig, reduced, register

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    d_ff=25600,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
)

SMOKE = reduced(
    CONFIG,
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=192,
    vocab_size=256,
)

register(CONFIG, SMOKE)
