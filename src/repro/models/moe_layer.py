"""Mixture-of-Experts FFN with *gapped* capacity dispatch.

Paper tie-in (Cole & Ramachandran): concurrent writers must not share blocks.
The expert buffers are 'gapped' — each expert's token slab is padded to a
multiple of the hardware tile (sublane=8) so no two experts' slabs share a
tile, and the dispatch offsets are computed with a prefix-sums (PS) scan,
the paper's canonical Type-1 HBP computation.

Two dispatch implementations:
  * ``sort``   — production path: argsort by expert id + scatter/gather.
                 O(Nk log Nk) work, O(E*C*d) memory; shardable (expert axis).
  * ``onehot`` — reference path: dense one-hot dispatch einsum.  O(N*E*C)
                 memory — only viable for tiny shapes; used as the oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.sharding_hints import constrain

SUBLANE = 8  # f32 sublane tile; the 'gap' quantum


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def gapped_capacity(n_tokens: int, n_experts: int, k: int, capacity_factor: float) -> int:
    c = int(-(-n_tokens * k * capacity_factor // n_experts))  # ceil
    return max(round_up(c, SUBLANE), SUBLANE)


def router(x, w_router, k: int):
    """x: (N, d); returns (weights (N,k) fp32, experts (N,k) int32, aux loss)."""
    logits = jnp.einsum("nd,de->ne", x.astype(jnp.float32), w_router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)
    # load-balance auxiliary loss (Switch-style)
    n_experts = w_router.shape[-1]
    me = jnp.mean(probs, axis=0)  # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_e, n_experts, dtype=jnp.float32), axis=1), axis=0
    )
    aux = n_experts * jnp.sum(me * ce)
    return top_p, top_e, aux


def expert_ffn(h, e_gate, e_up, e_down):
    """h: (E, C, d); expert weights (E, d, f)/(E, f, d).  The per-expert
    matmuls resolve their backend through the ambient policy
    (``common.expert_project``: the kernel registry vmapped over experts)."""
    from repro.models import common

    g = common.expert_project(h, e_gate)
    u = common.expert_project(h, e_up)
    a = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * u
    return common.expert_project(a, e_down)


def moe_ffn_sort(x, w_router, e_gate, e_up, e_down, *, k: int, capacity_factor: float,
                 n_groups: int = 1):
    """Sort-based gapped dispatch, grouped for SPMD scale.

    ``n_groups`` partitions the tokens into independent dispatch groups (one
    per data shard under the PWS planner) so the argsort / scatter / gather
    are per-group and shard cleanly — the global dispatch would otherwise be
    replicated by GSPMD (measured: a 68 GB gather for olmoe train_4k).  Each
    group gets its own gapped capacity — exactly how per-device expert
    capacity works in production EP systems, and the paper's balance
    condition: equal-size groups, each sharing O(1) blocks per expert slab.

    x: (N, d) -> (y (N, d), aux).
    """
    n, d = x.shape
    n_experts = e_gate.shape[0]
    if n % n_groups != 0 or n_groups < 1:
        n_groups = 1
    g = n_groups
    nl = n // g  # tokens per group
    cap = gapped_capacity(nl, n_experts, k, capacity_factor)

    top_p, top_e, aux = router(x, w_router, k)  # (N, k)

    flat_e = top_e.reshape(g, nl * k)
    flat_p = top_p.reshape(g, nl * k)
    src_tok = jnp.broadcast_to(jnp.arange(nl * k, dtype=jnp.int32) // k, (g, nl * k))

    def group_indices(fe):
        """Per-group dispatch indices — pure int32 index math (tiny tensors,
        cheap even if GSPMD replicates them).  PS scan for expert offsets.
        Returns: slot_src (E*cap,): source flat-entry of each expert slot
        (sentinel nl*k = padding); dest (nl*k,): slot of each flat entry
        (sentinel E*cap = dropped)."""
        order = jnp.argsort(fe, stable=True)
        se = fe[order]
        counts = jax.ops.segment_sum(jnp.ones_like(fe), fe, num_segments=n_experts)
        offsets = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
        rank = jnp.arange(nl * k, dtype=jnp.int32) - offsets[se].astype(jnp.int32)
        dest_sorted = jnp.where(rank < cap, se * cap + rank, n_experts * cap)
        # slot -> sorted position -> original flat entry
        slot_src = jnp.full((n_experts * cap + 1,), nl * k, jnp.int32)
        slot_src = slot_src.at[dest_sorted].set(order.astype(jnp.int32))[: n_experts * cap]
        # original flat entry -> slot
        inv = jnp.argsort(order)  # original -> sorted position
        dest = dest_sorted[inv]
        return slot_src, dest

    slot_src, dest = jax.vmap(group_indices)(flat_e)  # (g, E*cap), (g, nl*k)

    # data plane: batched GATHERS only (GSPMD partitions these cleanly over
    # the group axis; scatters of activation-sized tensors would replicate)
    xg = constrain(x.reshape(g, nl, d), "batch", "*", "*")
    # flat entry i corresponds to token i // k: gather token rows per slot
    tok_of_slot = jnp.minimum(slot_src // k, nl - 1)
    pad_mask = (slot_src >= nl * k)[..., None]
    h = jnp.take_along_axis(xg, tok_of_slot[..., None], axis=1)
    h = jnp.where(pad_mask, jnp.zeros((), h.dtype), h)
    h = h.reshape(g, n_experts, cap, d)
    h = constrain(h, "batch", "experts", "*", "*")

    # expert FFN products through the registry-resolving per-expert matmul
    # (ROADMAP PR-4 follow-on: MoE expert matmuls on the kernel substrate)
    from repro.models import common

    gq = common.expert_project(h, e_gate)
    up = common.expert_project(h, e_up)
    act = jax.nn.silu(gq.astype(jnp.float32)).astype(h.dtype) * up
    y_e = common.expert_project(act, e_down)
    y_e = constrain(y_e, "batch", "experts", "*", "*")

    y_flat = jnp.concatenate(
        [y_e.reshape(g, n_experts * cap, d), jnp.zeros((g, 1, d), y_e.dtype)], axis=1)
    contrib = jnp.take_along_axis(y_flat, dest[..., None], axis=1)  # (g, nl*k, d)
    contrib = contrib.reshape(g, nl, k, d) * flat_p.reshape(g, nl, k, 1).astype(contrib.dtype)
    y = jnp.sum(contrib, axis=2).reshape(n, d)
    return constrain(y.astype(x.dtype), "batch", "*"), aux


def moe_ffn_onehot(x, w_router, e_gate, e_up, e_down, *, k: int, capacity_factor: float):
    """Reference dense one-hot dispatch (oracle for tests; tiny shapes only)."""
    n, d = x.shape
    n_experts = e_gate.shape[0]
    cap = gapped_capacity(n, n_experts, k, capacity_factor)

    top_p, top_e, aux = router(x, w_router, k)
    # position of token within each expert's buffer
    onehot = jax.nn.one_hot(top_e, n_experts, dtype=jnp.int32)  # (N, k, E)
    sel = jnp.sum(onehot, axis=1)  # (N, E) 0/1 per (token, expert)
    pos = jnp.cumsum(sel, axis=0) - 1  # (N, E) rank within expert
    keep = (sel > 0) & (pos < cap)
    disp = (keep[:, :, None] & (jax.nn.one_hot(pos, cap, dtype=jnp.int32) > 0)).astype(x.dtype)
    h = jnp.einsum("nec,nd->ecd", disp, x)
    y_e = expert_ffn(h, e_gate, e_up, e_down)
    weight_ne = jnp.zeros((n, n_experts), jnp.float32)
    weight_ne = weight_ne.at[jnp.arange(n)[:, None], top_e].add(top_p)
    y = jnp.einsum("nec,ecd->nd", disp.astype(jnp.float32) * weight_ne[:, :, None], y_e.astype(jnp.float32))
    return y.astype(x.dtype), aux


def moe_ffn(x, w_router, e_gate, e_up, e_down, *, k: int, capacity_factor: float,
            dispatch: str = "sort", n_groups: int = 1):
    """``dispatch`` selects the token-dispatch algorithm ("sort" production
    path | "onehot" reference) — an algorithm choice, not a kernel backend;
    backends resolve through the ambient execution policy inside."""
    if dispatch == "sort":
        return moe_ffn_sort(x, w_router, e_gate, e_up, e_down, k=k,
                            capacity_factor=capacity_factor, n_groups=n_groups)
    return moe_ffn_onehot(x, w_router, e_gate, e_up, e_down, k=k,
                          capacity_factor=capacity_factor)
