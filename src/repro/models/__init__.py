"""Model zoo: build any assigned architecture from its config."""
from __future__ import annotations

from typing import Optional

from repro.configs.base import ModelConfig
from repro.models.base import Model, RunOptions


def build_model(cfg: ModelConfig, opts: Optional[RunOptions] = None) -> Model:
    from repro.models.dense import DenseLM
    from repro.models.encdec import EncDecLM
    from repro.models.hybrid import HybridLM
    from repro.models.ssm import SSMLM
    from repro.models.vlm import VisionLM

    family_map = {
        "dense": DenseLM,
        "moe": DenseLM,  # MoE layers live inside DenseLM
        "vlm": VisionLM,
        "hybrid": HybridLM,
        "ssm": SSMLM,
        "audio": EncDecLM,
    }
    try:
        cls = family_map[cfg.family]
    except KeyError:
        raise ValueError(f"unknown family {cfg.family!r}") from None
    return cls(cfg, opts)


__all__ = ["Model", "RunOptions", "build_model"]
