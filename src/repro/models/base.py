"""Model API shared by all architecture families.

A model is resource-oblivious: it never references the mesh, device count,
cache/block sizes.  All distribution decisions live in the PWS planner
(``repro.core.planner``) and the launchers.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Params = Any
Cache = Any


class UnsupportedFamilyError(TypeError):
    """A model family does not satisfy the serving contract.  Raised by the
    continuous-batching engine when construction finds a missing method;
    carries the family and the first missing contract name so callers (and
    tests) can assert on the structured fields instead of message text."""

    def __init__(self, family: str, missing: str):
        self.family = family
        self.missing = missing
        super().__init__(
            f"model family {family!r} does not implement the serving "
            f"contract: missing {missing!r} (required: init_cache -> "
            f"DecodeCache, prefill_chunk, per-row decode_step)")


@dataclass(frozen=True)
class RunOptions:
    """Execution options — the knobs the perf hillclimb turns.  Defaults are
    the paper-faithful baseline."""

    remat: str = "full"  # "none" | "full"
    ce_chunk: int = 512
    # blockwise attention tile sizes (BP leaf sizes); None = derived from the
    # queried device by the kernel planner (repro.kernels.planner)
    q_block: Optional[int] = None
    kv_block: Optional[int] = None
    # DEPRECATED compat shim (use repro.kernels.policy / the launchers'
    # --impl flag): non-default values are translated by Model.__init__ into
    # a scoped ExecutionPolicy applied around loss/prefill/decode_step, so
    # the old knobs produce identical dispatch decisions to the equivalent
    # explicit policy.  "auto" defers to the ambient policy.
    attention_impl: str = "auto"
    # DEPRECATED compat shim twin for model matmuls (gated MLP, QKV/output
    # projections, logits) — see attention_impl.
    matmul_impl: str = "auto"
    # measured-autotune mode for kernel dispatch: "off" | "replay" | "search";
    # None = resolved by the kernel planner (REPRO_AUTOTUNE, default "replay",
    # a no-op on a cold tile cache).  Launchers pin the resolved mode at
    # startup via repro.kernels.autotune.startup; a non-None value also joins
    # the model's compat policy scope.
    autotune: Optional[str] = None
    # beyond-paper optimizations (off in the baseline)
    use_banded_local: bool = False  # banded sliding-window attention
    causal_block_skip: bool = False  # triangular blockwise attention
    windowed_decode_cache: bool = False  # ring-buffer cache for local layers
    moe_dispatch: str = "sort"  # "sort" (prod) | "onehot" (reference)
    moe_groups: int = 1  # dispatch groups (set to dp size by the planner)
    fused_qkv: bool = False  # single QKV projection matmul
    microbatches: int = 1  # gradient-accumulation microbatches


class Model:
    """Family-agnostic interface used by train/serve/dryrun."""

    def __init__(self, cfg: ModelConfig, opts: Optional[RunOptions] = None):
        from repro.kernels import planner, policy  # kernels never import models

        self.cfg = cfg
        raw = opts or RunOptions()
        # fill planner-owned tile fields (q_block/kv_block) from the queried
        # device and the model's real head geometry / activation dtype —
        # models stay resource-oblivious, the substrate decides
        self.opts = planner.resolve_run_options(
            raw, head_dim=cfg.head_dim_, dtype=cfg.activation_dtype)
        # deprecated RunOptions backend knobs -> a scoped ExecutionPolicy
        # around the public entry points (tracing happens at Python level,
        # so the scope governs every dispatch the trace performs).  Built
        # from the *raw* options: a planner-filled autotune default must not
        # masquerade as an explicit user choice
        self._policy_updates = policy.from_run_options(raw)
        if self._policy_updates is not None:
            for name in ("loss", "prefill", "prefill_chunk", "decode_step"):
                setattr(self, name,
                        policy.bind(self._policy_updates, getattr(self, name)))

    # -- construction ------------------------------------------------------
    def init(self, rng: jax.Array) -> Params:
        raise NotImplementedError

    # -- training ----------------------------------------------------------
    def loss(self, params: Params, batch: dict) -> jax.Array:
        raise NotImplementedError

    # -- inference ---------------------------------------------------------
    def init_cache(self, batch_size: int, max_len: int) -> Cache:
        raise NotImplementedError

    def prefill(self, params: Params, batch: dict, max_len: int):
        """Returns (last_token_logits, cache)."""
        raise NotImplementedError

    def prefill_chunk(self, params: Params, tokens: jax.Array, offset,
                      cache: Cache, *, first: bool = False, lens=None,
                      extras: Optional[dict] = None):
        """One padded prefill chunk over the full cache batch.

        tokens: (b, s); offset: scalar or per-row (b,) context depths
        already in the cache; lens: optional (b,) valid-token counts
        (None = the whole chunk is valid on every row; 0 parks a row —
        its cache state must come through unchanged).  ``first`` is a
        static flag marking each request's first chunk (modality
        frontends / scale calibration run there).  Returns
        (per-row last-valid-token logits (b, V), cache)."""
        raise NotImplementedError

    def decode_step(self, params: Params, tokens: jax.Array, pos: jax.Array, cache: Cache,
                    extras: Optional[dict] = None):
        """tokens: (b, 1); pos: scalar current length or per-row (b,)
        positions.  Returns (logits, cache)."""
        raise NotImplementedError

    # -- dry-run plumbing ----------------------------------------------------
    def batch_extras_specs(self, batch_size: int, seq_len: int) -> dict:
        """ShapeDtypeStructs for modality-frontend stub inputs (VLM/audio)."""
        return {}


def stacked_init(per_layer_init, key: jax.Array, n_layers: int):
    """vmap a single-layer init over layer keys -> stacked (L, ...) params."""
    keys = jax.random.split(key, n_layers)
    return jax.vmap(per_layer_init)(keys)


def maybe_remat(fn, opts: RunOptions):
    if opts.remat == "full":
        # prevent_cse=False is the documented setting for remat-inside-scan:
        # the loop structure already prevents CSE, and the CSE barrier
        # otherwise materializes f32 copies of the carry.
        return jax.checkpoint(fn, prevent_cse=False)
    return fn


def right_shift(tokens: jax.Array, bos: int = 1) -> jax.Array:
    """Teacher-forcing input from target tokens."""
    return jnp.concatenate([jnp.full_like(tokens[:, :1], bos), tokens[:, :-1]], axis=1)
