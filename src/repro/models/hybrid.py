"""Hybrid RG-LRU + local-attention model (recurrentgemma-2b).

Block pattern (rec, rec, attn) — 1 attention per 2 recurrent blocks.  26
layers = 8 superblocks x (rec, rec, attn) + 2 trailing rec blocks.

Paper tie-in: the RG-LRU linear recurrence h_t = a_t*h_{t-1} + b_t is computed
with ``jax.lax.associative_scan`` — a balanced binary tree over the sequence,
i.e. literally the paper's BP computation (down-pass = pair combines, up-pass
= prefix fix-up).  The TPU kernel twin is ``repro.kernels.bp_scan``.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.base import Model, maybe_remat, right_shift, stacked_init

LRU_C = 8.0  # RG-LRU exponent constant from Griffin


def rglru_scan(a, b, h0=None):
    """h_t = a_t * h_{t-1} + b_t via associative (BP) scan.
    a, b: (batch, seq, width) fp32.  Returns h (batch, seq, width)."""
    if h0 is not None:
        # fold initial state into the first step
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def block_diag_linear(x, w):
    """x: (..., nh, wb); w: (nh, wb, wb) block-diagonal linear."""
    return jnp.einsum("...hi,hij->...hj", x, w)


def causal_conv1d(x, w, state=None):
    """Depthwise causal conv.  x: (b, s, w); w: (k, w).
    state: (b, k-1, w) previous inputs (decode).  Returns (y, new_state)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (b, s+k-1, w)
    y = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(k))
    new_state = xp[:, -(k - 1):] if k > 1 else None
    return y, new_state


class HybridLM(Model):
    @property
    def _n_super(self):
        return self.cfg.n_layers // len(self.cfg.block_pattern)  # 8

    @property
    def _n_tail(self):
        return self.cfg.n_layers - self._n_super * len(self.cfg.block_pattern)  # 2

    def init(self, rng):
        cfg = self.cfg
        dt = cfg.activation_dtype
        d, w, hd = cfg.d_model, cfg.lru_width, cfg.head_dim_
        nh = cfg.n_heads
        wb = w // nh
        k_emb, k_rec1, k_rec2, k_attn, k_tail = jax.random.split(rng, 5)

        def rec_block(key):
            ks = jax.random.split(key, 10)
            return {
                "ln1": jnp.zeros((d,), dt),
                "ln2": jnp.zeros((d,), dt),
                "w_x": common.dense_init(ks[0], (d, w), dt),
                "w_gate_branch": common.dense_init(ks[1], (d, w), dt),
                "conv_w": common.dense_init(ks[2], (cfg.conv1d_width, w), dt, scale=0.3),
                "lru_a_gate": common.dense_init(ks[3], (nh, wb, wb), jnp.float32),
                "lru_i_gate": common.dense_init(ks[4], (nh, wb, wb), jnp.float32),
                "lru_a_param": jnp.log(jnp.expm1(jnp.linspace(0.9, 0.999, w))).astype(jnp.float32),
                "w_out": common.dense_init(ks[5], (w, d), dt),
                "w_mlp_gate": common.dense_init(ks[6], (d, cfg.d_ff), dt),
                "w_mlp_up": common.dense_init(ks[7], (d, cfg.d_ff), dt),
                "w_mlp_down": common.dense_init(ks[8], (cfg.d_ff, d), dt),
            }

        def attn_block(key):
            ks = jax.random.split(key, 8)
            return {
                "ln1": jnp.zeros((d,), dt),
                "ln2": jnp.zeros((d,), dt),
                "wq": common.dense_init(ks[0], (d, cfg.q_dim), dt),
                "wk": common.dense_init(ks[1], (d, cfg.kv_dim), dt),
                "wv": common.dense_init(ks[2], (d, cfg.kv_dim), dt),
                "wo": common.dense_init(ks[3], (cfg.q_dim, d), dt),
                "w_mlp_gate": common.dense_init(ks[4], (d, cfg.d_ff), dt),
                "w_mlp_up": common.dense_init(ks[5], (d, cfg.d_ff), dt),
                "w_mlp_down": common.dense_init(ks[6], (cfg.d_ff, d), dt),
            }

        return {
            "embed": common.dense_init(k_emb, (cfg.vocab_size, d), dt, scale=0.02),
            "groups": {
                "rec1": stacked_init(rec_block, k_rec1, self._n_super),
                "rec2": stacked_init(rec_block, k_rec2, self._n_super),
                "attn": stacked_init(attn_block, k_attn, self._n_super),
            },
            "tail_rec": stacked_init(rec_block, k_tail, self._n_tail),
            "final_norm": jnp.zeros((d,), dt),
        }

    # -- blocks ----------------------------------------------------------------
    def _rec_block(self, pl, x, lru_state=None, conv_state=None):
        """Returns (x, new_lru_state, new_conv_state)."""
        cfg = self.cfg
        b, s, d = x.shape
        w = cfg.lru_width
        nh = cfg.n_heads
        wb = w // nh
        h = common.rms_norm(x, pl["ln1"], cfg.norm_eps)
        branch = common.constrain(jnp.einsum("bsd,dw->bsw", h, pl["w_x"]), "batch", "*", "ffn")
        gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", h, pl["w_gate_branch"]).astype(jnp.float32))
        gate = common.constrain(gate, "batch", "*", "ffn")
        y, new_conv = causal_conv1d(branch, pl["conv_w"], conv_state)

        # RG-LRU gates (block-diagonal linears, fp32)
        yh = y.astype(jnp.float32).reshape(b, s, nh, wb)
        r = jax.nn.sigmoid(block_diag_linear(yh, pl["lru_a_gate"])).reshape(b, s, w)
        i = jax.nn.sigmoid(block_diag_linear(yh, pl["lru_i_gate"])).reshape(b, s, w)
        log_a = -LRU_C * jax.nn.softplus(pl["lru_a_param"]) * r  # (b, s, w)
        a = jnp.exp(log_a)
        gated_in = i * y.astype(jnp.float32)
        bterm = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated_in

        if s == 1 and lru_state is not None:
            hseq = a * lru_state[:, None] + bterm  # single decode step
        else:
            hseq = rglru_scan(a, bterm, h0=lru_state)
        new_state = hseq[:, -1]  # (b, w)

        out = (hseq * gate).astype(x.dtype)
        x = x + common.constrain(jnp.einsum("bsw,wd->bsd", out, pl["w_out"]), "batch", "seq", "*")
        h2 = common.rms_norm(x, pl["ln2"], cfg.norm_eps)
        x = x + common.gated_mlp(h2, pl["w_mlp_gate"], pl["w_mlp_up"], pl["w_mlp_down"])
        return x, new_state, new_conv

    def _attn_block(self, pl, x, q_pos, k_pos, kc=None, vc=None, write_at=None):
        cfg = self.cfg
        b, s, d = x.shape
        hd = cfg.head_dim_
        h = common.rms_norm(x, pl["ln1"], cfg.norm_eps)
        q, k, v = common.qkv_project(h, pl["wq"], pl["wk"], pl["wv"])
        q = q.reshape(b, s, cfg.n_heads, hd)
        k = k.reshape(b, s, cfg.n_kv_heads, hd)
        v = v.reshape(b, s, cfg.n_kv_heads, hd)
        q = common.constrain(q, "batch", "*", "heads", "*")
        k = common.constrain(k, "batch", "*", "kv_heads", "*")
        v = common.constrain(v, "batch", "*", "kv_heads", "*")
        q = common.apply_rope(q, q_pos, cfg.rope_theta)
        k = common.apply_rope(k, q_pos, cfg.rope_theta)
        if kc is not None:
            cache_len = kc.shape[1]
            if s > cache_len:
                # ring-buffer prefill: keep only the last W positions; slot of
                # position p is p mod W, i.e. roll the tail by (end % W)
                shift = (write_at + s) % cache_len
                kc = jnp.roll(k[:, -cache_len:], shift, axis=1)
                vc = jnp.roll(v[:, -cache_len:], shift, axis=1)
            else:
                kc = jax.lax.dynamic_update_slice_in_dim(kc, k, write_at, axis=1)
                vc = jax.lax.dynamic_update_slice_in_dim(vc, v, write_at, axis=1)
            if s > 1:
                # prefill: attend over the fresh (in-order) k/v; the cache is
                # output-only here
                k_att, v_att, kp = k, v, q_pos
            else:
                k_att, v_att, kp = kc, vc, k_pos
        else:
            k_att, v_att, kp = k, v, k_pos
        # the ring-buffer decode cache is the one path that may not take the
        # kernel route: slot j holds position (write_at + j) mod W — a
        # *rotation*, violating the flash kernel's contiguous-positions
        # contract (it would causally mask the rolled-over half of the
        # window).  A scoped policy pin records the exception; every other
        # path (train, prefill, linear-cache decode) follows the ambient
        # policy like the rest of the model
        from repro.kernels import policy  # lazy: kernels stay out of model import

        ring = bool(kc is not None and s == 1
                    and self.opts.windowed_decode_cache and cfg.sliding_window)
        with policy.pin_if(ring, "attention", "jnp",
                           reason="ring-buffer decode cache: slot order is a "
                                  "rotation of positions, outside the flash "
                                  "kernel's contiguous-positions contract"):
            o = common.attention(q, k_att, v_att, q_pos, kp, causal=True,
                                 window=cfg.sliding_window,
                                 use_banded_local=self.opts.use_banded_local and kc is None,
                                 block_threshold=max(self.opts.q_block, self.opts.kv_block))
        x = x + common.constrain(common.attn_out_project(o, pl["wo"]),
                                 "batch", "seq", "*")
        h2 = common.rms_norm(x, pl["ln2"], cfg.norm_eps)
        x = x + common.gated_mlp(h2, pl["w_mlp_gate"], pl["w_mlp_up"], pl["w_mlp_down"])
        return x, (kc, vc)

    # -- forward ------------------------------------------------------------------
    def _backbone(self, params, tokens, q_pos, k_pos, *, cache=None, write_at=None):
        cfg = self.cfg
        x = common.embed_lookup(params["embed"], tokens).astype(cfg.activation_dtype)
        x = common.constrain(x, "batch", "seq", "*")
        if cfg.scale_embeddings:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)

        def superblock(carry, xs):
            x = carry
            if cache is None:
                p1, p2, pa = xs
                st = {}
            else:
                p1, p2, pa, st = xs
            x, s1, c1 = self._rec_block(p1, x, st.get("lru1"), st.get("conv1"))
            x, s2, c2 = self._rec_block(p2, x, st.get("lru2"), st.get("conv2"))
            x, (kc, vc) = self._attn_block(pa, x, q_pos, k_pos,
                                           st.get("k"), st.get("v"), write_at)
            ys = None
            if cache is not None:
                ys = {"lru1": s1, "conv1": c1, "lru2": s2, "conv2": c2, "k": kc, "v": vc}
            return x, ys

        def tail_block(carry, xs):
            x = carry
            if cache is None:
                pl = xs
                st = {}
            else:
                pl, st = xs
            x, s1, c1 = self._rec_block(pl, x, st.get("lru"), st.get("conv"))
            ys = None if cache is None else {"lru": s1, "conv": c1}
            return x, ys

        sb = maybe_remat(superblock, self.opts) if cache is None else superblock
        tb = maybe_remat(tail_block, self.opts) if cache is None else tail_block

        g = params["groups"]
        xs = (g["rec1"], g["rec2"], g["attn"])
        if cache is not None:
            xs = xs + (cache["groups"],)
        x, ys_g = jax.lax.scan(sb, x, xs)
        xs_t = params["tail_rec"] if cache is None else (params["tail_rec"], cache["tail"])
        x, ys_t = jax.lax.scan(tb, x, xs_t)
        x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
        new_cache = None if cache is None else {"groups": ys_g, "tail": ys_t}
        return x, new_cache

    def loss(self, params, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        inputs = right_shift(tokens)
        s = tokens.shape[1]
        pos = jnp.arange(s, dtype=jnp.int32)
        x, _ = self._backbone(params, inputs, pos, pos)
        return common.chunked_softmax_xent(x, params["embed"], labels, chunk=self.opts.ce_chunk)

    # -- inference -------------------------------------------------------------------
    def _attn_cache_len(self, max_len):
        # local attention never looks back further than the window
        if self.opts.windowed_decode_cache and self.cfg.sliding_window:
            return min(max_len, self.cfg.sliding_window)
        return max_len

    def init_cache(self, batch_size, max_len):
        cfg = self.cfg
        dt = cfg.activation_dtype
        w, kcw = cfg.lru_width, cfg.conv1d_width
        n_sb, n_tail = self._n_super, self._n_tail
        s_att = self._attn_cache_len(max_len)
        kv = (n_sb, batch_size, s_att, cfg.n_kv_heads, cfg.head_dim_)
        return {
            "groups": {
                "lru1": jnp.zeros((n_sb, batch_size, w), jnp.float32),
                "conv1": jnp.zeros((n_sb, batch_size, kcw - 1, w), dt),
                "lru2": jnp.zeros((n_sb, batch_size, w), jnp.float32),
                "conv2": jnp.zeros((n_sb, batch_size, kcw - 1, w), dt),
                "k": jnp.zeros(kv, dt),
                "v": jnp.zeros(kv, dt),
            },
            "tail": {
                "lru": jnp.zeros((n_tail, batch_size, w), jnp.float32),
                "conv": jnp.zeros((n_tail, batch_size, kcw - 1, w), dt),
            },
        }

    def prefill(self, params, batch, max_len):
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        q_pos = jnp.arange(s, dtype=jnp.int32)
        k_pos = jnp.arange(max_len, dtype=jnp.int32)
        cache = self.init_cache(b, max_len)
        x, new_cache = self._backbone(params, tokens, q_pos, k_pos, cache=cache, write_at=0)
        logits = common.logits_matmul(x[:, -1], params["embed"])
        return logits, new_cache

    def decode_step(self, params, tokens, pos, cache, extras=None):
        cfg = self.cfg
        max_len = cache["groups"]["k"].shape[2]  # (n_sb, b, S, kvh, hd)
        q_pos = jnp.full((1,), pos, jnp.int32)
        if self.opts.windowed_decode_cache and cfg.sliding_window:
            # ring buffer: slot j holds true position pos - ((pos - j) mod W)
            idx = jnp.arange(max_len, dtype=jnp.int32)
            ring_pos = pos - ((pos - idx) % max_len)
            k_pos = jnp.where(ring_pos >= 0, ring_pos, -(1 << 30))
            write_at = pos % max_len
        else:
            k_pos = jnp.arange(max_len, dtype=jnp.int32)
            write_at = pos
        x, new_cache = self._backbone(params, tokens, q_pos, k_pos, cache=cache,
                                      write_at=write_at)
        logits = common.logits_matmul(x[:, -1], params["embed"])
        return logits, new_cache
