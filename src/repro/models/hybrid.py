"""Hybrid RG-LRU + local-attention model (recurrentgemma-2b).

Block pattern (rec, rec, attn) — 1 attention per 2 recurrent blocks.  26
layers = 8 superblocks x (rec, rec, attn) + 2 trailing rec blocks.

Paper tie-in: the RG-LRU linear recurrence h_t = a_t*h_{t-1} + b_t is computed
with ``jax.lax.associative_scan`` — a balanced binary tree over the sequence,
i.e. literally the paper's BP computation (down-pass = pair combines, up-pass
= prefix fix-up).  The TPU kernel twin is ``repro.kernels.bp_scan``.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models import cache as dcache
from repro.models.base import Model, maybe_remat, right_shift, stacked_init

LRU_C = 8.0  # RG-LRU exponent constant from Griffin


def rglru_scan(a, b, h0=None):
    """h_t = a_t * h_{t-1} + b_t via associative (BP) scan.
    a, b: (batch, seq, width) fp32.  Returns h (batch, seq, width)."""
    if h0 is not None:
        # fold initial state into the first step
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def block_diag_linear(x, w):
    """x: (..., nh, wb); w: (nh, wb, wb) block-diagonal linear."""
    return jnp.einsum("...hi,hij->...hj", x, w)


def causal_conv1d(x, w, state=None, lens=None):
    """Depthwise causal conv.  x: (b, s, w); w: (k, w).
    state: (b, k-1, w) previous inputs (decode).  ``lens`` (b,) restricts
    the new state to each row's valid prefix (padded chunk: row r has
    consumed ``lens[r]`` real tokens; ``lens = 0`` keeps the old state).
    Returns (y, new_state)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (b, s+k-1, w)
    y = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(k))
    if k <= 1:
        new_state = None
    elif lens is None:
        new_state = xp[:, -(k - 1):]
    else:
        new_state = dcache.conv_tail(xp, lens, k - 1)
    return y, new_state


class HybridLM(Model):
    @property
    def _n_super(self):
        return self.cfg.n_layers // len(self.cfg.block_pattern)  # 8

    @property
    def _n_tail(self):
        return self.cfg.n_layers - self._n_super * len(self.cfg.block_pattern)  # 2

    def init(self, rng):
        cfg = self.cfg
        dt = cfg.activation_dtype
        d, w, hd = cfg.d_model, cfg.lru_width, cfg.head_dim_
        nh = cfg.n_heads
        wb = w // nh
        k_emb, k_rec1, k_rec2, k_attn, k_tail = jax.random.split(rng, 5)

        def rec_block(key):
            ks = jax.random.split(key, 10)
            return {
                "ln1": jnp.zeros((d,), dt),
                "ln2": jnp.zeros((d,), dt),
                "w_x": common.dense_init(ks[0], (d, w), dt),
                "w_gate_branch": common.dense_init(ks[1], (d, w), dt),
                "conv_w": common.dense_init(ks[2], (cfg.conv1d_width, w), dt, scale=0.3),
                "lru_a_gate": common.dense_init(ks[3], (nh, wb, wb), jnp.float32),
                "lru_i_gate": common.dense_init(ks[4], (nh, wb, wb), jnp.float32),
                "lru_a_param": jnp.log(jnp.expm1(jnp.linspace(0.9, 0.999, w))).astype(jnp.float32),
                "w_out": common.dense_init(ks[5], (w, d), dt),
                "w_mlp_gate": common.dense_init(ks[6], (d, cfg.d_ff), dt),
                "w_mlp_up": common.dense_init(ks[7], (d, cfg.d_ff), dt),
                "w_mlp_down": common.dense_init(ks[8], (cfg.d_ff, d), dt),
            }

        def attn_block(key):
            ks = jax.random.split(key, 8)
            return {
                "ln1": jnp.zeros((d,), dt),
                "ln2": jnp.zeros((d,), dt),
                "wq": common.dense_init(ks[0], (d, cfg.q_dim), dt),
                "wk": common.dense_init(ks[1], (d, cfg.kv_dim), dt),
                "wv": common.dense_init(ks[2], (d, cfg.kv_dim), dt),
                "wo": common.dense_init(ks[3], (cfg.q_dim, d), dt),
                "w_mlp_gate": common.dense_init(ks[4], (d, cfg.d_ff), dt),
                "w_mlp_up": common.dense_init(ks[5], (d, cfg.d_ff), dt),
                "w_mlp_down": common.dense_init(ks[6], (cfg.d_ff, d), dt),
            }

        return {
            "embed": common.dense_init(k_emb, (cfg.vocab_size, d), dt, scale=0.02),
            "groups": {
                "rec1": stacked_init(rec_block, k_rec1, self._n_super),
                "rec2": stacked_init(rec_block, k_rec2, self._n_super),
                "attn": stacked_init(attn_block, k_attn, self._n_super),
            },
            "tail_rec": stacked_init(rec_block, k_tail, self._n_tail),
            "final_norm": jnp.zeros((d,), dt),
        }

    # -- blocks ----------------------------------------------------------------
    def _rec_block(self, pl, x, lru_state=None, conv_state=None, lens=None):
        """Returns (x, new_lru_state, new_conv_state).

        ``lens`` (b,) restricts the state update to each row's valid
        prefix (padded chunk / parked engine row): pad steps carry the
        LRU identity (a = 1, b = 0 — ``h`` holds) and the conv state
        slices at the valid tail, so a ``lens = 0`` row's state passes
        through bitwise-untouched."""
        cfg = self.cfg
        b, s, d = x.shape
        w = cfg.lru_width
        nh = cfg.n_heads
        wb = w // nh
        h = common.rms_norm(x, pl["ln1"], cfg.norm_eps)
        branch = common.constrain(jnp.einsum("bsd,dw->bsw", h, pl["w_x"]), "batch", "*", "ffn")
        gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", h, pl["w_gate_branch"]).astype(jnp.float32))
        gate = common.constrain(gate, "batch", "*", "ffn")
        y, new_conv = causal_conv1d(branch, pl["conv_w"], conv_state, lens=lens)

        # RG-LRU gates (block-diagonal linears, fp32)
        yh = y.astype(jnp.float32).reshape(b, s, nh, wb)
        r = jax.nn.sigmoid(block_diag_linear(yh, pl["lru_a_gate"])).reshape(b, s, w)
        i = jax.nn.sigmoid(block_diag_linear(yh, pl["lru_i_gate"])).reshape(b, s, w)
        log_a = -LRU_C * jax.nn.softplus(pl["lru_a_param"]) * r  # (b, s, w)
        gated_in = i * y.astype(jnp.float32)
        bterm = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated_in
        tok = dcache.token_mask(lens, s)
        if tok is not None:
            # pad tokens are scan identities: a = exp(0) = 1, b = 0
            log_a = jnp.where(tok[..., None], log_a, 0.0)
            bterm = jnp.where(tok[..., None], bterm, 0.0)
        a = jnp.exp(log_a)

        if s == 1 and lru_state is not None:
            hseq = a * lru_state[:, None] + bterm  # single decode step
        else:
            hseq = rglru_scan(a, bterm, h0=lru_state)
        new_state = hseq[:, -1]  # (b, w)

        out = (hseq * gate).astype(x.dtype)
        x = x + common.constrain(jnp.einsum("bsw,wd->bsd", out, pl["w_out"]), "batch", "seq", "*")
        h2 = common.rms_norm(x, pl["ln2"], cfg.norm_eps)
        x = x + common.gated_mlp(h2, pl["w_mlp_gate"], pl["w_mlp_up"], pl["w_mlp_down"])
        return x, new_state, new_conv

    def _attn_block(self, pl, x, q_pos, k_pos, kc=None, vc=None, write_at=None,
                    ring=False, chunked=False, kv_len=None):
        cfg = self.cfg
        b, s, d = x.shape
        hd = cfg.head_dim_
        h = common.rms_norm(x, pl["ln1"], cfg.norm_eps)
        q, k, v = common.qkv_project(h, pl["wq"], pl["wk"], pl["wv"])
        q = q.reshape(b, s, cfg.n_heads, hd)
        k = k.reshape(b, s, cfg.n_kv_heads, hd)
        v = v.reshape(b, s, cfg.n_kv_heads, hd)
        q = common.constrain(q, "batch", "*", "heads", "*")
        k = common.constrain(k, "batch", "*", "kv_heads", "*")
        v = common.constrain(v, "batch", "*", "kv_heads", "*")
        q = common.apply_rope(q, q_pos, cfg.rope_theta)
        k = common.apply_rope(k, q_pos, cfg.rope_theta)
        if kc is not None:
            if ring:
                kc = dcache.ring_write(kc, k, write_at)
                vc = dcache.ring_write(vc, v, write_at)
            else:
                kc = dcache.linear_write(kc, k, write_at)
                vc = dcache.linear_write(vc, v, write_at)
            if s == 1 or chunked:
                k_att, v_att, kp = kc, vc, k_pos
            else:
                # prefill: attend over the fresh (in-order) k/v; the cache is
                # output-only here
                k_att, v_att, kp = k, v, q_pos
        else:
            k_att, v_att, kp = k, v, k_pos
        # Ring decode rides the SAME flash kernel as every linear layout:
        # RingKV's wrap-aware mapping supplies kv_len = min(pos+1, C) with
        # q_offset = pos, so an unwrapped row attends its contiguous prefix
        # and a wrapped row attends the whole ring (slot order is a softmax
        # permutation; C <= window keeps every live slot in-window, so the
        # static window mask is dropped and the jnp oracle masks causally
        # over RingKV.slot_positions instead).
        attend_ring = kc is not None and ring and (s == 1 or chunked)
        window = None if attend_ring else cfg.sliding_window
        o = common.attention(q, k_att, v_att, q_pos, kp, causal=True,
                             window=window,
                             kv_len=kv_len if attend_ring else None,
                             use_banded_local=self.opts.use_banded_local and kc is None,
                             block_threshold=max(self.opts.q_block, self.opts.kv_block))
        x = x + common.constrain(common.attn_out_project(o, pl["wo"]),
                                 "batch", "seq", "*")
        h2 = common.rms_norm(x, pl["ln2"], cfg.norm_eps)
        x = x + common.gated_mlp(h2, pl["w_mlp_gate"], pl["w_mlp_up"], pl["w_mlp_down"])
        return x, (kc, vc)

    # -- forward ------------------------------------------------------------------
    def _backbone(self, params, tokens, q_pos, k_pos, *, cache=None,
                  write_at=None, lens=None, chunked=False, kv_len=None):
        cfg = self.cfg
        x = common.embed_lookup(params["embed"], tokens).astype(cfg.activation_dtype)
        x = common.constrain(x, "batch", "seq", "*")
        if cfg.scale_embeddings:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
        ring = cache is not None and isinstance(cache["kv"], dcache.RingKV)

        def superblock(carry, xs):
            x = carry
            if cache is None:
                p1, p2, pa = xs
                l1 = c1 = l2 = c2 = kc = vc = None
            else:
                p1, p2, pa, l1, c1, l2, c2, kc, vc = xs
            x, s1, nc1 = self._rec_block(p1, x, l1, c1, lens=lens)
            x, s2, nc2 = self._rec_block(p2, x, l2, c2, lens=lens)
            x, (kc2, vc2) = self._attn_block(pa, x, q_pos, k_pos, kc, vc,
                                             write_at, ring=ring,
                                             chunked=chunked, kv_len=kv_len)
            ys = None if cache is None else (s1, nc1, s2, nc2, kc2, vc2)
            return x, ys

        def tail_block(carry, xs):
            x = carry
            if cache is None:
                pl = xs
                l = c = None
            else:
                pl, l, c = xs
            x, s1, c1 = self._rec_block(pl, x, l, c, lens=lens)
            ys = None if cache is None else (s1, c1)
            return x, ys

        sb = maybe_remat(superblock, self.opts) if cache is None else superblock
        tb = maybe_remat(tail_block, self.opts) if cache is None else tail_block

        g = params["groups"]
        xs = (g["rec1"], g["rec2"], g["attn"])
        if cache is not None:
            st = cache["state"].states
            kv = cache["kv"]
            xs = xs + (st["lru1"], st["conv1"], st["lru2"], st["conv2"],
                       kv.k, kv.v)
        x, ys_g = jax.lax.scan(sb, x, xs)
        if cache is None:
            xs_t = params["tail_rec"]
        else:
            st = cache["state"].states
            xs_t = (params["tail_rec"], st["tail_lru"], st["tail_conv"])
        x, ys_t = jax.lax.scan(tb, x, xs_t)
        x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
        if cache is None:
            return x, None
        s1, c1, s2, c2, kc, vc = ys_g
        tl, tc = ys_t
        new_cache = {
            "state": cache["state"].replace(states={
                "lru1": s1, "conv1": c1, "lru2": s2, "conv2": c2,
                "tail_lru": tl, "tail_conv": tc}),
            "kv": cache["kv"].replace(k=kc, v=vc),
        }
        return x, new_cache

    def loss(self, params, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        inputs = right_shift(tokens)
        s = tokens.shape[1]
        pos = jnp.arange(s, dtype=jnp.int32)
        x, _ = self._backbone(params, inputs, pos, pos)
        return common.chunked_softmax_xent(x, params["embed"], labels, chunk=self.opts.ce_chunk)

    # -- inference -------------------------------------------------------------------
    @property
    def _ring_mode(self):
        # local attention never looks back further than the window
        return bool(self.opts.windowed_decode_cache and self.cfg.sliding_window)

    def init_cache(self, batch_size, max_len):
        cfg = self.cfg
        dt = cfg.activation_dtype
        w, kcw = cfg.lru_width, cfg.conv1d_width
        n_sb, n_tail = self._n_super, self._n_tail
        state = dcache.StateCarry.create({
            "lru1": jnp.zeros((n_sb, batch_size, w), jnp.float32),
            "conv1": jnp.zeros((n_sb, batch_size, kcw - 1, w), dt),
            "lru2": jnp.zeros((n_sb, batch_size, w), jnp.float32),
            "conv2": jnp.zeros((n_sb, batch_size, kcw - 1, w), dt),
            "tail_lru": jnp.zeros((n_tail, batch_size, w), jnp.float32),
            "tail_conv": jnp.zeros((n_tail, batch_size, kcw - 1, w), dt),
        })
        if self._ring_mode:
            kv = dcache.RingKV.create(
                (n_sb,), batch_size, min(max_len, cfg.sliding_window),
                cfg.n_kv_heads, cfg.head_dim_, dt)
        else:
            kv = dcache.LinearKV.create(
                (n_sb,), batch_size, max_len, cfg.n_kv_heads, cfg.head_dim_,
                dt)
        return {"state": state, "kv": kv}

    def prefill(self, params, batch, max_len):
        tokens = batch["tokens"]
        b, s = tokens.shape
        q_pos = jnp.arange(s, dtype=jnp.int32)
        k_pos = jnp.arange(s, dtype=jnp.int32)
        cache = self.init_cache(b, max_len)
        x, new_cache = self._backbone(params, tokens, q_pos, k_pos, cache=cache, write_at=0)
        logits = common.logits_matmul(x[:, -1], params["embed"])
        new_cache["kv"] = new_cache["kv"].replace(pos=jnp.full((b,), s, jnp.int32))
        return logits, new_cache

    def prefill_chunk(self, params, tokens, offset, cache, *, first=False,
                      lens=None, extras=None):
        """Chunked prefill against the linear layout (the engine path).
        Ring mode is decode-only by construction — a windowed chunked
        prefill would have to wrap-attend mid-prompt, and the engine serves
        hybrid with the linear layout (the window is still enforced by the
        attention mask)."""
        if isinstance(cache["kv"], dcache.RingKV):
            raise NotImplementedError(
                "chunked prefill over the RingKV layout: serve hybrid with "
                "windowed_decode_cache=False (window enforced by masking)")
        b, s = tokens.shape
        offset = jnp.asarray(offset, jnp.int32)
        q_pos = (offset[:, None] if offset.ndim else offset) + \
            jnp.arange(s, dtype=jnp.int32)
        k_pos = jnp.arange(cache["kv"].capacity, dtype=jnp.int32)
        x, new_cache = self._backbone(params, tokens, q_pos, k_pos,
                                      cache=cache, write_at=offset,
                                      lens=lens, chunked=not first)
        logits = common.logits_matmul(dcache.pick_last(x, lens),
                                      params["embed"])
        new_pos = jnp.broadcast_to(
            offset + (s if lens is None else jnp.asarray(lens, jnp.int32)),
            (b,))
        new_cache["kv"] = new_cache["kv"].replace(pos=new_pos)
        return logits, new_cache

    def decode_step(self, params, tokens, pos, cache, extras=None):
        b = tokens.shape[0]
        kv = cache["kv"]
        pos = jnp.asarray(pos, jnp.int32)
        q_pos = pos[:, None] if pos.ndim else jnp.full((1,), pos, jnp.int32)
        if isinstance(kv, dcache.RingKV):
            kv_len = kv.attend_lens(pos)        # per-row live-slot counts
            k_pos = kv.slot_positions(pos)      # true positions (jnp oracle)
        else:
            kv_len = None
            k_pos = jnp.arange(kv.capacity, dtype=jnp.int32)
        # parked engine rows (valid = False) carry their state through the
        # step untouched; the lockstep path has every row valid, where the
        # masking is the identity
        lens = cache["state"].valid.astype(jnp.int32)
        x, new_cache = self._backbone(params, tokens, q_pos, k_pos,
                                      cache=cache, write_at=pos, lens=lens,
                                      kv_len=kv_len)
        logits = common.logits_matmul(x[:, -1], params["embed"])
        new_cache["kv"] = new_cache["kv"].replace(
            pos=jnp.broadcast_to(pos + 1, (b,)))
        return logits, new_cache
