"""VLM backbone (llama-3.2-vision-90b): decoder LM where every
``cross_attn_every``-th layer is a gated cross-attention layer over
precomputed image patch embeddings (vision frontend is a STUB per the
assignment: ``input_specs()`` provides the patch embeddings).

Structure: scan over superblocks of (cross_attn_every - 1) self-attn layers
+ 1 cross-attn layer.  100 layers -> 20 superblocks of (4 self + 1 cross).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models import cache as dcache
from repro.models.base import Model, maybe_remat, right_shift, stacked_init


class VisionLM(Model):
    @property
    def _n_super(self):
        return self.cfg.n_layers // self.cfg.cross_attn_every

    @property
    def _n_self_per(self):
        return self.cfg.cross_attn_every - 1

    def init(self, rng):
        cfg = self.cfg
        dt = cfg.activation_dtype
        d, hd = cfg.d_model, cfg.head_dim_
        k_emb, k_self, k_cross, k_head = jax.random.split(rng, 4)

        def self_layer(key):
            ks = jax.random.split(key, 8)
            return {
                "ln1": jnp.zeros((d,), dt),
                "ln2": jnp.zeros((d,), dt),
                "wq": common.dense_init(ks[0], (d, cfg.q_dim), dt),
                "wk": common.dense_init(ks[1], (d, cfg.kv_dim), dt),
                "wv": common.dense_init(ks[2], (d, cfg.kv_dim), dt),
                "wo": common.dense_init(ks[3], (cfg.q_dim, d), dt),
                "w_gate": common.dense_init(ks[4], (d, cfg.d_ff), dt),
                "w_up": common.dense_init(ks[5], (d, cfg.d_ff), dt),
                "w_down": common.dense_init(ks[6], (cfg.d_ff, d), dt),
            }

        def cross_layer(key):
            p = self_layer(key)
            p["xgate_attn"] = jnp.zeros((), dt)  # tanh-gated cross-attn
            p["xgate_ffn"] = jnp.zeros((), dt)
            p["q_norm"] = jnp.zeros((hd,), dt)
            p["k_norm"] = jnp.zeros((hd,), dt)
            return p

        n_sb, n_self = self._n_super, self._n_self_per

        def self_group(key):
            return stacked_init(self_layer, key, n_self)

        params = {
            "embed": common.dense_init(k_emb, (cfg.vocab_size, d), dt, scale=0.02),
            "self_layers": stacked_init(self_group, k_self, n_sb),  # (n_sb, n_self, ...)
            "cross_layers": stacked_init(cross_layer, k_cross, n_sb),  # (n_sb, ...)
            "final_norm": jnp.zeros((d,), dt),
            "lm_head": common.dense_init(k_head, (cfg.vocab_size, d), dt, scale=0.02),
        }
        return params

    # -- blocks --------------------------------------------------------------
    def _self_attn_block(self, pl, x, q_pos, k_pos, kc=None, vc=None, write_at=None):
        cfg = self.cfg
        b, s, _ = x.shape
        hd = cfg.head_dim_
        h = common.rms_norm(x, pl["ln1"], cfg.norm_eps)
        q, k, v = common.qkv_project(h, pl["wq"], pl["wk"], pl["wv"])
        q = q.reshape(b, s, cfg.n_heads, hd)
        k = k.reshape(b, s, cfg.n_kv_heads, hd)
        v = v.reshape(b, s, cfg.n_kv_heads, hd)
        q = common.constrain(q, "batch", "*", "heads", "*")
        k = common.constrain(k, "batch", "*", "kv_heads", "*")
        v = common.constrain(v, "batch", "*", "kv_heads", "*")
        q = common.apply_rope(q, q_pos, cfg.rope_theta)
        k = common.apply_rope(k, q_pos, cfg.rope_theta)
        if kc is not None:
            kc = dcache.linear_write(kc, k, write_at)
            vc = dcache.linear_write(vc, v, write_at)
            k, v = kc, vc
        o = common.attention(q, k, v, q_pos, k_pos, causal=True,
                             block_threshold=max(self.opts.q_block, self.opts.kv_block))
        o = common.constrain(common.attn_out_project(o, pl["wo"]),
                             "batch", "seq", "*")
        x = x + o
        h = common.rms_norm(x, pl["ln2"], cfg.norm_eps)
        x = x + common.gated_mlp(h, pl["w_gate"], pl["w_up"], pl["w_down"])
        return x, (kc, vc)

    def _cross_attn_block(self, pl, x, img_k, img_v):
        """img_k/img_v: precomputed (b, n_img, kvh, hd) from patch embeddings."""
        cfg = self.cfg
        b, s, _ = x.shape
        hd = cfg.head_dim_
        h = common.rms_norm(x, pl["ln1"], cfg.norm_eps)
        q = common.project(h, pl["wq"]).reshape(b, s, cfg.n_heads, hd)
        q = common.rms_norm(q, pl["q_norm"], cfg.norm_eps)
        n_img = img_k.shape[1]
        q_pos = jnp.zeros((s,), jnp.int32)
        k_pos = jnp.zeros((n_img,), jnp.int32)
        o = common.attention_dense(q, img_k, img_v, q_pos, k_pos, causal=False)
        o = common.constrain(common.attn_out_project(o, pl["wo"]),
                             "batch", "seq", "*")
        x = x + jnp.tanh(pl["xgate_attn"].astype(jnp.float32)).astype(x.dtype) * o
        h = common.rms_norm(x, pl["ln2"], cfg.norm_eps)
        m = common.gated_mlp(h, pl["w_gate"], pl["w_up"], pl["w_down"])
        return x + jnp.tanh(pl["xgate_ffn"].astype(jnp.float32)).astype(x.dtype) * m

    def _image_kv(self, pl_cross, img):
        """Compute cross-attn K/V from patch embeddings for one cross layer."""
        cfg = self.cfg
        b, n_img, _ = img.shape
        hd = cfg.head_dim_
        k = common.project(img, pl_cross["wk"]).reshape(b, n_img, cfg.n_kv_heads, hd)
        v = common.project(img, pl_cross["wv"]).reshape(b, n_img, cfg.n_kv_heads, hd)
        k = common.rms_norm(k, pl_cross["k_norm"], cfg.norm_eps)
        return k, v

    # -- forward ---------------------------------------------------------------
    def _backbone(self, params, tokens, img, q_pos, k_pos, *, caches=None, write_at=None,
                  img_kv=None):
        cfg = self.cfg
        x = common.embed_lookup(params["embed"], tokens).astype(cfg.activation_dtype)
        x = common.constrain(x, "batch", "seq", "*")

        def superblock(carry, xs):
            x = carry
            pls, plc = xs[0], xs[1]
            kcs = vcs = None
            if caches is not None:
                kcs, vcs = xs[2], xs[3]
            new_kc, new_vc = [], []
            for i in range(self._n_self_per):
                pl_i = jax.tree.map(lambda a: a[i], pls)
                kc_i = None if kcs is None else kcs[i]
                vc_i = None if vcs is None else vcs[i]
                x, (kc2, vc2) = self._self_attn_block(pl_i, x, q_pos, k_pos, kc_i, vc_i, write_at)
                new_kc.append(kc2)
                new_vc.append(vc2)
            if img_kv is not None:
                ik, iv = xs[-2], xs[-1]
            else:
                ik, iv = self._image_kv(plc, img)
            x = self._cross_attn_block(plc, x, ik, iv)
            ys = None
            if caches is not None:
                ys = (jnp.stack(new_kc), jnp.stack(new_vc))
            return x, ys

        xs = [params["self_layers"], params["cross_layers"]]
        if caches is not None:
            xs += [caches[0], caches[1]]
        if img_kv is not None:
            xs += [img_kv[0], img_kv[1]]
        sb = maybe_remat(superblock, self.opts) if caches is None else superblock
        x, ys = jax.lax.scan(sb, x, tuple(xs))
        x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x, ys

    def loss(self, params, batch):
        cfg = self.cfg
        tokens, labels, img = batch["tokens"], batch["labels"], batch["image_embeds"]
        inputs = right_shift(tokens)
        s = tokens.shape[1]
        pos = jnp.arange(s, dtype=jnp.int32)
        x, _ = self._backbone(params, inputs, img, pos, pos)
        return common.chunked_softmax_xent(x, params["lm_head"], labels, chunk=self.opts.ce_chunk)

    # -- inference ---------------------------------------------------------------
    def init_cache(self, batch_size, max_len):
        cfg = self.cfg
        return {
            "self": dcache.LinearKV.create(
                (self._n_super, self._n_self_per), batch_size, max_len,
                cfg.n_kv_heads, cfg.head_dim_, cfg.activation_dtype),
            "img": dcache.CrossKV.create(
                (self._n_super,), batch_size, cfg.n_image_tokens,
                cfg.n_kv_heads, cfg.head_dim_, cfg.activation_dtype),
        }

    def _all_image_kv(self, params, img):
        def per_layer(plc):
            return self._image_kv(plc, img)
        return jax.lax.map(per_layer, params["cross_layers"])

    def prefill(self, params, batch, max_len):
        tokens, img = batch["tokens"], batch["image_embeds"]
        b, s = tokens.shape
        q_pos = jnp.arange(s, dtype=jnp.int32)
        k_pos = jnp.arange(max_len, dtype=jnp.int32)
        cache = self.init_cache(b, max_len)
        img_k, img_v = self._all_image_kv(params, img)
        x, (kc, vc) = self._backbone(
            params, tokens, None, q_pos, k_pos,
            caches=(cache["self"].k, cache["self"].v), write_at=0,
            img_kv=(img_k, img_v),
        )
        logits = common.logits_matmul(x[:, -1], params["lm_head"])
        return logits, {
            "self": cache["self"].replace(k=kc, v=vc,
                                          pos=jnp.full((b,), s, jnp.int32)),
            "img": cache["img"].replace(k=img_k, v=img_v),
        }

    def prefill_chunk(self, params, tokens, offset, cache, *, first=False,
                      lens=None, extras=None):
        """Chunked prefill: the first chunk computes each live row's image
        k/v from ``extras["image_embeds"]`` and freezes them (rows with
        ``lens = 0`` keep their stored slabs); every chunk writes
        self-attention k/v at its per-row offset and attends the cache
        prefix causally."""
        b, s = tokens.shape
        self_kv, img = cache["self"], cache["img"]
        offset = jnp.asarray(offset, jnp.int32)
        q_pos = (offset[:, None] if offset.ndim else offset) + \
            jnp.arange(s, dtype=jnp.int32)
        k_pos = jnp.arange(self_kv.capacity, dtype=jnp.int32)
        if first:
            ik, iv = self._all_image_kv(params, extras["image_embeds"])
            if lens is not None:
                live = jnp.asarray(lens) > 0
                ik = dcache.masked_rows(live, ik, img.k, axis=1)
                iv = dcache.masked_rows(live, iv, img.v, axis=1)
            img = img.replace(k=ik, v=iv)
        x, (kc, vc) = self._backbone(
            params, tokens, None, q_pos, k_pos,
            caches=(self_kv.k, self_kv.v), write_at=offset,
            img_kv=(img.k, img.v),
        )
        logits = common.logits_matmul(dcache.pick_last(x, lens),
                                      params["lm_head"])
        new_pos = jnp.broadcast_to(
            offset + (s if lens is None else jnp.asarray(lens, jnp.int32)),
            (b,))
        return logits, {"self": self_kv.replace(k=kc, v=vc, pos=new_pos),
                        "img": img}

    def decode_step(self, params, tokens, pos, cache, extras=None):
        b = tokens.shape[0]
        self_kv, img = cache["self"], cache["img"]
        pos = jnp.asarray(pos, jnp.int32)
        # scalar: lockstep; (b,) vector: per-row continuous-batching decode
        q_pos = pos[:, None] if pos.ndim else jnp.full((1,), pos, jnp.int32)
        k_pos = jnp.arange(self_kv.capacity, dtype=jnp.int32)
        x, (kc, vc) = self._backbone(
            params, tokens, None, q_pos, k_pos,
            caches=(self_kv.k, self_kv.v), write_at=pos,
            img_kv=(img.k, img.v),
        )
        logits = common.logits_matmul(x[:, -1], params["lm_head"])
        new_self = self_kv.replace(k=kc, v=vc,
                                   pos=jnp.broadcast_to(pos + 1, (b,)))
        return logits, {"self": new_self, "img": img}

    def batch_extras_specs(self, batch_size, seq_len):
        cfg = self.cfg
        return {
            "image_embeds": jax.ShapeDtypeStruct(
                (batch_size, cfg.n_image_tokens, cfg.d_model), cfg.activation_dtype
            )
        }
