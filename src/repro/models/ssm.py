"""Mamba-2 (SSD — state-space duality) attention-free LM (mamba2-370m).

Paper tie-in: the SSD chunked algorithm IS the paper's two-pass BP prefix
computation — pass 1 computes per-chunk partial sums (intra-chunk outputs +
chunk states, the BP down-pass leaves), pass 2 scans chunk states across
chunks (the second BP pass of the paper's PS algorithm).  The chunk length
is the BP leaf size; the cross-chunk scan is O(seq/chunk) sequential steps
of O(1) state each — `repro.kernels.bp_scan` is the kernel twin.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models import cache as dcache
from repro.models.base import Model, maybe_remat, right_shift, stacked_init
from repro.models.hybrid import causal_conv1d


def segsum(a):
    """a: (..., Q).  Returns (..., Q, Q) with out[..., q, k] = sum_{i=k+1..q} a_i
    for q >= k, -inf otherwise (log of the decay matrix L)."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # sum_{i=k+1..q}
    iq = jnp.arange(q)
    mask = iq[:, None] >= iq[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, a, B, C, chunk: int, initial_state=None):
    """SSD forward.

    x: (b, l, h, p) inputs (already multiplied by dt)
    a: (b, l, h)    log-decay per step (dt * A, A negative)
    B: (b, l, n)    input projection to state (ngroups=1, shared across heads)
    C: (b, l, n)    output projection from state
    Returns (y (b, l, h, p), final_state (b, h, p, n)).
    """
    b, l, h, p = x.shape
    n = B.shape[-1]
    assert l % chunk == 0, (l, chunk)
    c = l // chunk

    xr = x.reshape(b, c, chunk, h, p)
    ar = a.reshape(b, c, chunk, h)
    Br = B.reshape(b, c, chunk, n)
    Cr = C.reshape(b, c, chunk, n)

    a_cum = jnp.cumsum(ar, axis=2)  # (b, c, Q, h)

    # 1. intra-chunk (diagonal block) outputs — BP leaves
    L = jnp.exp(segsum(ar.transpose(0, 1, 3, 2)))  # (b, c, h, Q, Q)
    y_diag = jnp.einsum("bcqn,bckn,bchqk,bckhp->bcqhp", Cr, Br, L, xr)

    # 2. per-chunk states (contribution of each chunk to its final state)
    decay_states = jnp.exp(a_cum[:, :, -1:, :] - a_cum)  # (b, c, Q, h)
    states = jnp.einsum("bckn,bckh,bckhp->bchpn", Br, decay_states, xr)

    # 3. inter-chunk recurrence — the second BP pass over chunk states
    chunk_decay = jnp.exp(a_cum[:, :, -1, :])  # (b, c, h)

    def step(s_prev, inp):
        dec, st = inp  # (b, h), (b, h, p, n)
        s_new = dec[..., None, None] * s_prev + st
        return s_new, s_prev  # emit state ENTERING the chunk

    s0 = initial_state if initial_state is not None else jnp.zeros((b, h, p, n), x.dtype)
    final_state, s_prev = jax.lax.scan(
        step, s0, (chunk_decay.transpose(1, 0, 2), states.transpose(1, 0, 2, 3, 4))
    )
    s_prev = s_prev.transpose(1, 0, 2, 3, 4)  # (b, c, h, p, n)

    # 4. inter-chunk (off-diagonal) outputs
    decay_out = jnp.exp(a_cum)  # (b, c, Q, h)
    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", Cr, s_prev, decay_out)

    y = (y_diag + y_off).reshape(b, l, h, p)
    return y, final_state


def ssd_decode_step(x, a, B, C, state):
    """Single-token SSD update.  x: (b, h, p); a: (b, h); B, C: (b, n).
    state: (b, h, p, n).  Returns (y (b,h,p), new_state)."""
    decay = jnp.exp(a)[..., None, None]  # (b, h, 1, 1)
    new_state = decay * state + jnp.einsum("bhp,bn->bhpn", x, B)
    y = jnp.einsum("bhpn,bn->bhp", new_state, C)
    return y, new_state


class SSMLM(Model):
    def init(self, rng):
        cfg = self.cfg
        dt = cfg.activation_dtype
        d = cfg.d_model
        di = cfg.ssm_d_inner
        ds = cfg.ssm_state
        nh = cfg.ssm_n_heads
        conv_dim = di + 2 * ds
        k_emb, k_layers = jax.random.split(rng)

        def one_layer(key):
            ks = jax.random.split(key, 6)
            return {
                "ln": jnp.zeros((d,), dt),
                "w_in": common.dense_init(ks[0], (d, 2 * di + 2 * ds + nh), dt),
                "conv_w": common.dense_init(ks[1], (cfg.conv1d_width, conv_dim), dt, scale=0.3),
                "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
                "dt_bias": jnp.log(jnp.expm1(jnp.exp(jnp.linspace(
                    jnp.log(0.001), jnp.log(0.1), nh)))).astype(jnp.float32),
                "D": jnp.ones((nh,), jnp.float32),
                "gn": jnp.zeros((di,), dt),  # gated RMSNorm weight
                "w_out": common.dense_init(ks[2], (di, d), dt),
            }

        return {
            "embed": common.dense_init(k_emb, (cfg.vocab_size, d), dt, scale=0.02),
            "layers": stacked_init(one_layer, k_layers, cfg.n_layers),
            "final_norm": jnp.zeros((d,), dt),
        }

    def _mix(self, pl, x, *, conv_state=None, ssm_state=None, single_step=False,
             lens=None):
        """The Mamba2 mixer.  Returns (y, new_conv_state, new_ssm_state).

        ``lens`` (b,) restricts the state update to each row's valid prefix
        (padded chunk / parked engine row): pad steps get dt = 0, i.e.
        decay exp(0) = 1 and zero input — exact SSD scan identities — and
        the conv state slices at the valid tail, so a ``lens = 0`` row's
        state passes through bitwise-untouched."""
        cfg = self.cfg
        b, s, d = x.shape
        di, ds, nh, hp = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_n_heads, cfg.ssm_head_dim

        zxbcdt = common.constrain(jnp.einsum("bsd,de->bse", x, pl["w_in"]),
                                  "batch", "*", "ffn")
        z, xbc, dt_raw = jnp.split(zxbcdt, [di, 2 * di + 2 * ds], axis=-1)
        xbc, new_conv = causal_conv1d(xbc, pl["conv_w"], conv_state, lens=lens)
        xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x.dtype)
        xs, B, C = jnp.split(xbc, [di, di + ds], axis=-1)

        dt_v = jax.nn.softplus(dt_raw.astype(jnp.float32) + pl["dt_bias"])  # (b, s, nh)
        tok = dcache.token_mask(lens, s)
        if tok is not None:
            dt_v = jnp.where(tok[..., None], dt_v, 0.0)
        A = -jnp.exp(pl["A_log"])  # (nh,)
        xh = xs.reshape(b, s, nh, hp).astype(jnp.float32)
        x_dt = xh * dt_v[..., None]
        a = dt_v * A  # (b, s, nh)

        if single_step:
            y, new_ssm = ssd_decode_step(
                x_dt[:, 0], a[:, 0], B[:, 0].astype(jnp.float32), C[:, 0].astype(jnp.float32),
                ssm_state,
            )
            y = y[:, None]  # (b, 1, nh, hp)
        else:
            if cfg.ssm_chunk is None:
                # BP leaf size from the kernel planner (the SSD chunk is the
                # scan kernel's block applied at the model layer)
                from repro.kernels import planner

                chunk = min(planner.plan_scan((b, s), jnp.float32)["block"], s)
            else:
                chunk = min(cfg.ssm_chunk, s)
            while s % chunk != 0:  # largest divisor <= the target chunk
                chunk -= 1
            y, new_ssm = ssd_chunked(
                x_dt, a, B.astype(jnp.float32), C.astype(jnp.float32),
                chunk=chunk, initial_state=ssm_state,
            )
        y = y + pl["D"][:, None] * xh
        y = y.reshape(b, s, di)
        # gated RMSNorm (Mamba2): norm(y * silu(z))
        y = y * jax.nn.silu(z.astype(jnp.float32))
        y = common.rms_norm(y.astype(x.dtype), pl["gn"], cfg.norm_eps)
        out = common.constrain(jnp.einsum("bse,ed->bsd", y, pl["w_out"]), "batch", "seq", "*")
        return out, new_conv, new_ssm

    def _backbone(self, params, tokens, *, cache=None, single_step=False,
                  lens=None):
        cfg = self.cfg
        x = common.embed_lookup(params["embed"], tokens).astype(cfg.activation_dtype)
        x = common.constrain(x, "batch", "seq", "*")

        def layer_fn(carry, xs):
            x = carry
            if cache is None:
                pl = xs
                cs = ss = None
            else:
                pl, cs, ss = xs
            h = common.rms_norm(x, pl["ln"], cfg.norm_eps)
            y, nc, ns = self._mix(pl, h, conv_state=cs, ssm_state=ss,
                                  single_step=single_step, lens=lens)
            ys = None if cache is None else (nc, ns)
            return x + y, ys

        fn = maybe_remat(layer_fn, self.opts) if cache is None else layer_fn
        xs = (params["layers"] if cache is None else
              (params["layers"], cache.states["conv"], cache.states["ssm"]))
        x, ys = jax.lax.scan(fn, x, xs)
        x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
        new_cache = (None if cache is None else
                     cache.replace(states={"conv": ys[0], "ssm": ys[1]}))
        return x, new_cache

    def loss(self, params, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        inputs = right_shift(tokens)
        x, _ = self._backbone(params, inputs)
        return common.chunked_softmax_xent(x, params["embed"], labels, chunk=self.opts.ce_chunk)

    # -- inference: state is O(1) in sequence length (the SSM advantage) -----
    def init_cache(self, batch_size, max_len):
        cfg = self.cfg
        di, ds = cfg.ssm_d_inner, cfg.ssm_state
        nh, hp = cfg.ssm_n_heads, cfg.ssm_head_dim
        conv_dim = di + 2 * ds
        return dcache.StateCarry.create({
            "conv": jnp.zeros((cfg.n_layers, batch_size, cfg.conv1d_width - 1, conv_dim),
                              cfg.activation_dtype),
            "ssm": jnp.zeros((cfg.n_layers, batch_size, nh, hp, ds), jnp.float32),
        })

    def prefill(self, params, batch, max_len):
        tokens = batch["tokens"]
        b, s = tokens.shape
        cache = self.init_cache(b, max_len)
        x, new_cache = self._backbone(params, tokens, cache=cache)
        logits = common.logits_matmul(x[:, -1], params["embed"])
        return logits, new_cache

    def prefill_chunk(self, params, tokens, offset, cache, *, first=False,
                      lens=None, extras=None):
        """Position-free chunked prefill: the carried state IS the context,
        so ``offset`` is ignored and chunks simply continue the scan.  Exact
        engine<->lockstep parity holds when chunk boundaries land on
        multiples of the SSD chunk (``cfg.ssm_chunk``): the pad tail of a
        partial chunk contributes exact scan identities."""
        x, new_cache = self._backbone(params, tokens, cache=cache, lens=lens)
        logits = common.logits_matmul(dcache.pick_last(x, lens),
                                      params["embed"])
        return logits, new_cache

    def decode_step(self, params, tokens, pos, cache, extras=None):
        # parked engine rows (valid = False) carry their state through the
        # step untouched; the lockstep path has every row valid, where the
        # masking is the identity
        lens = cache.valid.astype(jnp.int32)
        x, new_cache = self._backbone(params, tokens, cache=cache,
                                      single_step=True, lens=lens)
        logits = common.logits_matmul(x[:, -1], params["embed"])
        return logits, new_cache
