"""Encoder-decoder multimodal backbone (seamless-m4t-large-v2).

The speech frontend is a STUB per the assignment: the encoder consumes
precomputed frame embeddings (b, enc_len, d_model) from ``input_specs()``.
Decoder = causal self-attention + cross-attention over encoder output.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models import cache as dcache
from repro.models.base import Model, maybe_remat, right_shift, stacked_init


class EncDecLM(Model):
    def init(self, rng):
        cfg = self.cfg
        dt = cfg.activation_dtype
        d, hd = cfg.d_model, cfg.head_dim_
        k_emb, k_enc, k_dec, k_head = jax.random.split(rng, 4)

        def attn_params(key):
            ks = jax.random.split(key, 4)
            return {
                "wq": common.dense_init(ks[0], (d, cfg.q_dim), dt),
                "wk": common.dense_init(ks[1], (d, cfg.kv_dim), dt),
                "wv": common.dense_init(ks[2], (d, cfg.kv_dim), dt),
                "wo": common.dense_init(ks[3], (cfg.q_dim, d), dt),
            }

        def mlp_params(key):
            ks = jax.random.split(key, 3)
            return {
                "w_gate": common.dense_init(ks[0], (d, cfg.d_ff), dt),
                "w_up": common.dense_init(ks[1], (d, cfg.d_ff), dt),
                "w_down": common.dense_init(ks[2], (cfg.d_ff, d), dt),
            }

        def enc_layer(key):
            k1, k2 = jax.random.split(key)
            return {"ln1": jnp.zeros((d,), dt), "ln2": jnp.zeros((d,), dt),
                    "attn": attn_params(k1), "mlp": mlp_params(k2)}

        def dec_layer(key):
            k1, k2, k3 = jax.random.split(key, 3)
            return {"ln1": jnp.zeros((d,), dt), "ln2": jnp.zeros((d,), dt),
                    "ln3": jnp.zeros((d,), dt),
                    "self_attn": attn_params(k1), "cross_attn": attn_params(k2),
                    "mlp": mlp_params(k3)}

        return {
            "embed": common.dense_init(k_emb, (cfg.vocab_size, d), dt, scale=0.02),
            "encoder": stacked_init(enc_layer, k_enc, cfg.encoder_layers),
            "decoder": stacked_init(dec_layer, k_dec, cfg.n_layers),
            "enc_norm": jnp.zeros((d,), dt),
            "final_norm": jnp.zeros((d,), dt),
            "lm_head": common.dense_init(k_head, (cfg.vocab_size, d), dt, scale=0.02),
        }

    # -- attention helpers ------------------------------------------------------
    def _proj_qkv(self, pa, xq, xkv, q_pos, k_pos, rope=True):
        cfg = self.cfg
        b, sq, _ = xq.shape
        sk = xkv.shape[1]
        hd = cfg.head_dim_
        if xq is xkv:
            # self-attention: policy-fusable single QKV matmul
            q, k, v = common.qkv_project(xq, pa["wq"], pa["wk"], pa["wv"])
        else:
            # cross-attention: q and k/v read different activations
            q = common.project(xq, pa["wq"])
            k = common.project(xkv, pa["wk"])
            v = common.project(xkv, pa["wv"])
        q = q.reshape(b, sq, cfg.n_heads, hd)
        k = k.reshape(b, sk, cfg.n_kv_heads, hd)
        v = v.reshape(b, sk, cfg.n_kv_heads, hd)
        q = common.constrain(q, "batch", "*", "heads", "*")
        k = common.constrain(k, "batch", "*", "kv_heads", "*")
        v = common.constrain(v, "batch", "*", "kv_heads", "*")
        if rope:
            q = common.apply_rope(q, q_pos, cfg.rope_theta)
            k = common.apply_rope(k, k_pos, cfg.rope_theta)
        return q, k, v

    def _encoder(self, params, frames):
        """frames: (b, enc_len, d) stub embeddings -> encoder output."""
        cfg = self.cfg
        x = common.constrain(frames.astype(cfg.activation_dtype), "batch", "seq", "*")
        s = x.shape[1]
        pos = jnp.arange(s, dtype=jnp.int32)

        def layer_fn(x, pl):
            h = common.rms_norm(x, pl["ln1"], cfg.norm_eps)
            q, k, v = self._proj_qkv(pl["attn"], h, h, pos, pos)
            o = common.attention(q, k, v, pos, pos, causal=False,
                                 block_threshold=max(self.opts.q_block, self.opts.kv_block))
            x = x + common.constrain(
                common.attn_out_project(o, pl["attn"]["wo"]),
                "batch", "seq", "*")
            h = common.rms_norm(x, pl["ln2"], cfg.norm_eps)
            x = x + common.gated_mlp(h, pl["mlp"]["w_gate"], pl["mlp"]["w_up"], pl["mlp"]["w_down"])
            return x, None

        fn = maybe_remat(layer_fn, self.opts)
        x, _ = jax.lax.scan(fn, x, params["encoder"])
        return common.rms_norm(x, params["enc_norm"], cfg.norm_eps)

    def _decoder(self, params, tokens, enc_out, q_pos, k_pos, *, caches=None, write_at=None,
                 cross_kv=None):
        cfg = self.cfg
        b = tokens.shape[0]
        x = common.constrain(common.embed_lookup(params["embed"], tokens).astype(cfg.activation_dtype),
                             "batch", "seq", "*")
        s = x.shape[1]
        enc_pos = None if enc_out is None else jnp.arange(enc_out.shape[1], dtype=jnp.int32)

        def layer_fn(carry, xs):
            x = carry
            pl = xs[0]
            kc = vc = None
            if caches is not None:
                kc, vc = xs[1], xs[2]
            h = common.rms_norm(x, pl["ln1"], cfg.norm_eps)
            q, k, v = self._proj_qkv(pl["self_attn"], h, h, q_pos, q_pos)
            if kc is not None:
                kc = dcache.linear_write(kc, k, write_at)
                vc = dcache.linear_write(vc, v, write_at)
                k, v = kc, vc
            o = common.attention(q, k, v, q_pos, k_pos, causal=True,
                                 block_threshold=max(self.opts.q_block, self.opts.kv_block))
            x = x + common.constrain(
                common.attn_out_project(o, pl["self_attn"]["wo"]),
                "batch", "seq", "*")

            # cross attention
            h = common.rms_norm(x, pl["ln2"], cfg.norm_eps)
            if cross_kv is not None:
                xk, xv = xs[-2], xs[-1]
                hd = cfg.head_dim_
                xq = common.project(h, pl["cross_attn"]["wq"]).reshape(
                    b, s, cfg.n_heads, hd)
                cp = jnp.zeros((xk.shape[1],), jnp.int32)
                o = common.attention_dense(xq, xk, xv, jnp.zeros((s,), jnp.int32), cp, causal=False)
            else:
                xq, xk, xv = self._proj_qkv(pl["cross_attn"], h, enc_out, enc_pos, enc_pos,
                                            rope=False)
                o = common.attention(xq, xk, xv, jnp.zeros((s,), jnp.int32),
                                     jnp.zeros((enc_out.shape[1],), jnp.int32), causal=False,
                                     block_threshold=max(self.opts.q_block, self.opts.kv_block))
            x = x + common.constrain(
                common.attn_out_project(o, pl["cross_attn"]["wo"]),
                "batch", "seq", "*")

            h = common.rms_norm(x, pl["ln3"], cfg.norm_eps)
            x = x + common.gated_mlp(h, pl["mlp"]["w_gate"], pl["mlp"]["w_up"], pl["mlp"]["w_down"])
            ys = None if caches is None else (kc, vc)
            return x, ys

        xs = [params["decoder"]]
        if caches is not None:
            xs += [caches[0], caches[1]]
        if cross_kv is not None:
            xs += [cross_kv[0], cross_kv[1]]
        fn = maybe_remat(layer_fn, self.opts) if caches is None else layer_fn
        x, ys = jax.lax.scan(fn, x, tuple(xs))
        return common.rms_norm(x, params["final_norm"], cfg.norm_eps), ys

    def _all_cross_kv(self, params, enc_out):
        cfg = self.cfg
        b, se, _ = enc_out.shape
        hd = cfg.head_dim_

        def per_layer(pl):
            k = common.project(enc_out, pl["cross_attn"]["wk"]).reshape(
                b, se, cfg.n_kv_heads, hd)
            v = common.project(enc_out, pl["cross_attn"]["wv"]).reshape(
                b, se, cfg.n_kv_heads, hd)
            return k, v

        return jax.lax.map(per_layer, params["decoder"])

    # -- API --------------------------------------------------------------------
    def loss(self, params, batch):
        tokens, labels, frames = batch["tokens"], batch["labels"], batch["audio_frames"]
        inputs = right_shift(tokens)
        s = tokens.shape[1]
        pos = jnp.arange(s, dtype=jnp.int32)
        enc_out = self._encoder(params, frames)
        x, _ = self._decoder(params, inputs, enc_out, pos, pos)
        return common.chunked_softmax_xent(x, params["lm_head"], labels, chunk=self.opts.ce_chunk)

    def enc_len(self, seq_len: int) -> int:
        return max(int(seq_len * self.cfg.encoder_len_ratio), 16)

    def init_cache(self, batch_size, max_len):
        cfg = self.cfg
        dt = cfg.activation_dtype
        return {
            "self": dcache.LinearKV.create(
                (cfg.n_layers,), batch_size, max_len, cfg.n_kv_heads,
                cfg.head_dim_, dt),
            "cross": dcache.CrossKV.create(
                (cfg.n_layers,), batch_size, self.enc_len(max_len),
                cfg.n_kv_heads, cfg.head_dim_, dt),
        }

    def prefill(self, params, batch, max_len):
        tokens, frames = batch["tokens"], batch["audio_frames"]
        b, s = tokens.shape
        q_pos = jnp.arange(s, dtype=jnp.int32)
        k_pos = jnp.arange(max_len, dtype=jnp.int32)
        enc_out = self._encoder(params, frames)
        xk, xv = self._all_cross_kv(params, enc_out)
        cache = self.init_cache(b, max_len)
        x, (kc, vc) = self._decoder(params, tokens, None, q_pos, k_pos,
                                    caches=(cache["self"].k, cache["self"].v),
                                    write_at=0, cross_kv=(xk, xv))
        logits = common.logits_matmul(x[:, -1], params["lm_head"])
        return logits, {
            "self": cache["self"].replace(k=kc, v=vc,
                                          pos=jnp.full((b,), s, jnp.int32)),
            "cross": cache["cross"].replace(k=xk, v=xv),
        }

    def prefill_chunk(self, params, tokens, offset, cache, *, first=False,
                      lens=None, extras=None):
        """Chunked prefill: the first chunk runs the (whole-utterance)
        encoder and freezes each live row's cross-attention k/v — rows with
        ``lens = 0`` keep their stored slabs, so a batched first-chunk
        launch cannot clobber a mid-decode neighbour — and every chunk
        writes self-attention k/v at its per-row offset and attends the
        cache prefix causally."""
        b, s = tokens.shape
        self_kv, cross = cache["self"], cache["cross"]
        offset = jnp.asarray(offset, jnp.int32)
        q_pos = (offset[:, None] if offset.ndim else offset) + \
            jnp.arange(s, dtype=jnp.int32)
        k_pos = jnp.arange(self_kv.capacity, dtype=jnp.int32)
        if first:
            enc_out = self._encoder(params, extras["audio_frames"])
            xk, xv = self._all_cross_kv(params, enc_out)
            if lens is not None:
                live = jnp.asarray(lens) > 0
                xk = dcache.masked_rows(live, xk, cross.k, axis=1)
                xv = dcache.masked_rows(live, xv, cross.v, axis=1)
            cross = cross.replace(k=xk, v=xv)
        x, (kc, vc) = self._decoder(params, tokens, None, q_pos, k_pos,
                                    caches=(self_kv.k, self_kv.v),
                                    write_at=offset,
                                    cross_kv=(cross.k, cross.v))
        logits = common.logits_matmul(dcache.pick_last(x, lens),
                                      params["lm_head"])
        new_pos = jnp.broadcast_to(
            offset + (s if lens is None else jnp.asarray(lens, jnp.int32)),
            (b,))
        return logits, {"self": self_kv.replace(k=kc, v=vc, pos=new_pos),
                        "cross": cross}

    def decode_step(self, params, tokens, pos, cache, extras=None):
        b = tokens.shape[0]
        self_kv, cross = cache["self"], cache["cross"]
        pos = jnp.asarray(pos, jnp.int32)
        # scalar: lockstep; (b,) vector: per-row continuous-batching decode
        q_pos = pos[:, None] if pos.ndim else jnp.full((1,), pos, jnp.int32)
        k_pos = jnp.arange(self_kv.capacity, dtype=jnp.int32)
        x, (kc, vc) = self._decoder(params, tokens, None, q_pos, k_pos,
                                    caches=(self_kv.k, self_kv.v),
                                    write_at=pos,
                                    cross_kv=(cross.k, cross.v))
        logits = common.logits_matmul(x[:, -1], params["lm_head"])
        new_self = self_kv.replace(k=kc, v=vc,
                                   pos=jnp.broadcast_to(pos + 1, (b,)))
        return logits, {"self": new_self, "cross": cross}

    def batch_extras_specs(self, batch_size, seq_len):
        cfg = self.cfg
        return {
            "audio_frames": jax.ShapeDtypeStruct(
                (batch_size, self.enc_len(seq_len), cfg.d_model), cfg.activation_dtype
            )
        }
