"""Dense decoder-only LM (also hosts MoE layers) — covers qwen2.5-14b,
qwen3-32b, qwen3-1.7b, gemma3-1b (5:1 local:global), olmoe-1b-7b,
qwen3-moe-30b-a3b.

The layer stack is a ``jax.lax.scan`` over layer-stacked parameters so the
HLO is O(1) in depth.  Per-layer heterogeneity (sliding window / RoPE theta
for Gemma3's 5:1 pattern) is *data*, carried as scanned inputs, so a single
program covers the whole pattern — the HBP balance condition at the layer
level.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models import cache as dcache
from repro.models.base import Model, RunOptions, maybe_remat, right_shift, stacked_init
from repro.models.moe_layer import moe_ffn

GLOBAL_WINDOW = 1 << 30  # sentinel: "no sliding window"


def layer_windows(cfg: ModelConfig) -> jnp.ndarray:
    """Per-layer attention window (GLOBAL_WINDOW = full causal)."""
    win = []
    for i in range(cfg.n_layers):
        if cfg.sliding_window is None:
            win.append(GLOBAL_WINDOW)
        elif cfg.global_every and (i % cfg.global_every == cfg.global_every - 1):
            win.append(GLOBAL_WINDOW)  # every k-th layer is global
        else:
            win.append(cfg.sliding_window)
    return jnp.asarray(win, jnp.int32)


def layer_thetas(cfg: ModelConfig) -> jnp.ndarray:
    """Gemma3 uses a small RoPE base for local layers, large for global."""
    th = []
    for i in range(cfg.n_layers):
        is_global = (cfg.sliding_window is None) or (
            cfg.global_every and i % cfg.global_every == cfg.global_every - 1
        )
        if cfg.sliding_window is not None and not is_global:
            th.append(10_000.0)
        else:
            th.append(cfg.rope_theta)
    return jnp.asarray(th, jnp.float32)


class DenseLM(Model):
    # -- params ------------------------------------------------------------
    def init(self, rng):
        cfg = self.cfg
        dt = cfg.activation_dtype
        d, hd = cfg.d_model, cfg.head_dim_
        k_emb, k_layers, k_head = jax.random.split(rng, 3)

        def one_layer(key):
            ks = jax.random.split(key, 12)
            p = {
                "ln1": jnp.zeros((d,), dt),
                "ln2": jnp.zeros((d,), dt),
                "wq": common.dense_init(ks[0], (d, cfg.q_dim), dt),
                "wk": common.dense_init(ks[1], (d, cfg.kv_dim), dt),
                "wv": common.dense_init(ks[2], (d, cfg.kv_dim), dt),
                "wo": common.dense_init(ks[3], (cfg.q_dim, d), dt),
            }
            if cfg.qkv_bias:
                p["bq"] = jnp.zeros((cfg.q_dim,), dt)
                p["bk"] = jnp.zeros((cfg.kv_dim,), dt)
                p["bv"] = jnp.zeros((cfg.kv_dim,), dt)
            if cfg.qk_norm:
                p["q_norm"] = jnp.zeros((hd,), dt)
                p["k_norm"] = jnp.zeros((hd,), dt)
            if cfg.n_experts:
                p["router"] = common.dense_init(ks[4], (d, cfg.n_experts), jnp.float32)
                p["e_gate"] = common.dense_init(ks[5], (cfg.n_experts, d, cfg.expert_d_ff), dt)
                p["e_up"] = common.dense_init(ks[6], (cfg.n_experts, d, cfg.expert_d_ff), dt)
                p["e_down"] = common.dense_init(ks[7], (cfg.n_experts, cfg.expert_d_ff, d), dt)
            else:
                p["w_gate"] = common.dense_init(ks[4], (d, cfg.d_ff), dt)
                p["w_up"] = common.dense_init(ks[5], (d, cfg.d_ff), dt)
                p["w_down"] = common.dense_init(ks[6], (cfg.d_ff, d), dt)
            return p

        params = {
            "embed": common.dense_init(k_emb, (cfg.vocab_size, d), dt, scale=0.02),
            "layers": stacked_init(one_layer, k_layers, cfg.n_layers),
            "final_norm": jnp.zeros((d,), dt),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = common.dense_init(k_head, (cfg.vocab_size, d), dt, scale=0.02)
        return params

    # -- shared layer body ---------------------------------------------------
    def _attn(self, pl, x, q_pos, k_pos, window, theta, k_cache=None, v_cache=None,
              write_at=None, k_scale=None, v_scale=None, chunked=False,
              calib_len=None):
        """Attention sub-block.  If caches given, write k/v at ``write_at`` and
        attend over the cache; else self-attention over x.

        ``q_pos`` may be per-row (b, s) — continuous-batching decode, every
        slot at its own depth — in which case ``write_at`` is a (b,) vector
        too (see ``cache.linear_write``).  ``chunked`` marks a continuation
        prefill chunk: the fresh k/v is written into the cache and attention
        runs over the cache prefix (causally masked to ``q_pos``) instead of
        the fresh slab, so a long prompt streams in fixed-size chunks.

        An int8 cache (the policy's attention ``kv_dtype`` variant, see
        ``init_cache``) carries per-(batch, kv_head) scales: prefill
        calibrates them from the fresh k/v (and attends the exact fp values,
        so prefill logits match the fp cache bit-for-bit); decode and
        continuation chunks quantize the step's k/v with the stored scales
        (calibrated on the first chunk) and attend the int8 cache — the
        kernel dequantizes inside the block load."""
        cfg = self.cfg
        b, s, d = x.shape
        hd = cfg.head_dim_
        h = common.rms_norm(x, pl["ln1"], cfg.norm_eps)
        # QKV through the registry-resolving projections; one fused
        # (d, q+k+v) matmul under the policy's qkv_fused variant
        q, k, v = common.qkv_project(h, pl["wq"], pl["wk"], pl["wv"])
        if cfg.qkv_bias:
            q, k, v = q + pl["bq"], k + pl["bk"], v + pl["bv"]
        q = common.constrain(q.reshape(b, s, cfg.n_heads, hd), "batch", "*", "heads", "*")
        k = common.constrain(k.reshape(b, s, cfg.n_kv_heads, hd), "batch", "*", "kv_heads", "*")
        v = common.constrain(v.reshape(b, s, cfg.n_kv_heads, hd), "batch", "*", "kv_heads", "*")
        if cfg.qk_norm:
            q = common.rms_norm(q, pl["q_norm"], cfg.norm_eps)
            k = common.rms_norm(k, pl["k_norm"], cfg.norm_eps)
        q = common.apply_rope(q, q_pos, theta)
        k = common.apply_rope(k, q_pos, theta)

        quantized = k_cache is not None and k_cache.dtype == jnp.int8
        if quantized and s > 1 and not chunked:
            # prefill: calibrate the per-(b, kvh) scales on the real k/v —
            # restricted to calib_len positions when the chunk is zero-padded.
            # Per-row calib_len means a batched first-chunk launch: rows with
            # no valid tokens (parked mid-decode) keep their stored scales
            ck = common.kv_scale(k, calib_len)
            cv = common.kv_scale(v, calib_len)
            if calib_len is not None and jnp.ndim(calib_len) == 1:
                live = calib_len > 0
                ck = dcache.masked_rows(live, ck, k_scale)
                cv = dcache.masked_rows(live, cv, v_scale)
            k_scale, v_scale = ck, cv
        if k_cache is not None:
            kw = common.quantize_kv(k, k_scale) if quantized else k
            vw = common.quantize_kv(v, v_scale) if quantized else v
            k_cache = dcache.linear_write(k_cache, kw, write_at)
            v_cache = dcache.linear_write(v_cache, vw, write_at)
        att_scales = {}
        if k_cache is not None and (s == 1 or chunked):
            # decode / continuation chunk: attend over the cache (the fresh
            # rows were just written — write-before-attend keeps every
            # attended slot valid)
            k_att, v_att = k_cache, v_cache
            if quantized:
                att_scales = {"k_scale": k_scale, "v_scale": v_scale}
        else:
            k_att, v_att, k_pos = k, v, q_pos  # train/prefill: fresh k/v

        o = common.attention(
            q, k_att, v_att, q_pos, k_pos,
            causal=True, window=window,
            use_banded_local=self.opts.use_banded_local and k_cache is None,
            block_threshold=max(self.opts.q_block, self.opts.kv_block),
            q_block=self.opts.q_block, kv_block=self.opts.kv_block,
            # active whenever we attend over fresh k/v (train AND prefill)
            causal_block_skip=self.opts.causal_block_skip and s > 1,
            **att_scales,
        )
        o = common.attn_out_project(o, pl["wo"])
        return (x + common.constrain(o, "batch", "seq", "*"),
                (k_cache, v_cache, k_scale, v_scale))

    def _ffn(self, pl, x):
        cfg = self.cfg
        h = common.rms_norm(x, pl["ln2"], cfg.norm_eps)
        if cfg.n_experts:
            b, s, d = h.shape
            y, aux = moe_ffn(
                h.reshape(b * s, d), pl["router"], pl["e_gate"], pl["e_up"], pl["e_down"],
                k=cfg.experts_per_token, capacity_factor=cfg.capacity_factor,
                dispatch=self.opts.moe_dispatch, n_groups=self.opts.moe_groups,
            )
            return x + y.reshape(b, s, d), aux
        return x + common.gated_mlp(h, pl["w_gate"], pl["w_up"],
                                    pl["w_down"]), jnp.zeros((), jnp.float32)

    # -- forward (training) --------------------------------------------------
    def _backbone(self, params, tokens, q_pos, k_pos, *, caches=None,
                  write_at=None, chunked=False, calib_len=None):
        """Runs the layer stack.  caches: optional stacked (k, v) — each
        (L,b,S,K,hd) — optionally followed by (k_scale, v_scale) stacked
        (L,b,K) when the cache is quantized.  Returns (hidden, new_caches,
        aux_sum)."""
        cfg = self.cfg
        x = common.embed_lookup(params["embed"], tokens).astype(cfg.activation_dtype)
        x = common.constrain(x, "batch", "seq", "*")
        if cfg.scale_embeddings:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)

        windows = layer_windows(cfg)
        thetas = layer_thetas(cfg)

        def layer_fn(carry, xs):
            x, aux = carry
            ks = vs = kc = vc = None
            if caches is None:
                pl, window, theta = xs
            elif len(caches) == 4:
                pl, window, theta, kc, vc, ks, vs = xs
            else:
                pl, window, theta, kc, vc = xs
            if cfg.sliding_window is None:
                # all-global pattern: the scanned sentinel is a tracer, but
                # the static fact "no window" must stay static — it gates the
                # (static-kwarg) Pallas attention route in common.attention
                window = None
            x, (kc2, vc2, ks2, vs2) = self._attn(
                pl, x, q_pos, k_pos, window, theta, k_cache=kc, v_cache=vc,
                write_at=write_at, k_scale=ks, v_scale=vs, chunked=chunked,
                calib_len=calib_len)
            x, a = self._ffn(pl, x)
            if caches is None:
                ys = None
            elif len(caches) == 4:
                ys = (kc2, vc2, ks2, vs2)
            else:
                ys = (kc2, vc2)
            return (x, aux + a), ys

        layer_fn = maybe_remat(layer_fn, self.opts) if caches is None else layer_fn
        xs = (params["layers"], windows, thetas)
        if caches is not None:
            xs = xs + tuple(caches)
        (x, aux), ys = jax.lax.scan(layer_fn, (x, jnp.zeros((), jnp.float32)), xs)
        x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x, ys, aux

    def _out_embed(self, params):
        return params["embed"] if self.cfg.tie_embeddings else params["lm_head"]

    def loss(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        labels = batch["labels"]
        inputs = right_shift(tokens)
        b, s = tokens.shape
        pos = jnp.arange(s, dtype=jnp.int32)
        x, _, aux = self._backbone(params, inputs, pos, pos)
        ce = common.chunked_softmax_xent(x, self._out_embed(params), labels,
                                         chunk=self.opts.ce_chunk)
        return ce + cfg.router_aux_weight * aux / max(cfg.n_layers, 1)

    # -- inference -----------------------------------------------------------
    def init_cache(self, batch_size, max_len):
        """``LinearKV`` cache (layer-stacked slabs, per-row positions),
        optionally quantized: under the policy's attention ``kv_dtype=int8``
        variant the k/v slabs are int8 with per-layer per-(batch, kv_head)
        f32 scales stored alongside (calibrated at prefill) — a quarter of
        the cache bytes, dequantized inside the attention kernel's block
        load."""
        cfg = self.cfg
        dtype, quantized = common.kv_cache_dtype(cfg.activation_dtype)
        return dcache.LinearKV.create(
            (cfg.n_layers,), batch_size, max_len, cfg.n_kv_heads,
            cfg.head_dim_, dtype, quantized=quantized)

    @staticmethod
    def _cache_tuple(kv: dcache.LinearKV):
        if kv.quantized:
            return (kv.k, kv.v, kv.k_scale, kv.v_scale)
        return (kv.k, kv.v)

    @staticmethod
    def _rebuild(kv: dcache.LinearKV, ys, new_pos):
        scales = ({"k_scale": ys[2], "v_scale": ys[3]} if len(ys) == 4 else {})
        return kv.replace(k=ys[0], v=ys[1], pos=new_pos, **scales)

    def prefill(self, params, batch, max_len):
        tokens = batch["tokens"]
        b, s = tokens.shape
        q_pos = jnp.arange(s, dtype=jnp.int32)
        k_pos = jnp.arange(max_len, dtype=jnp.int32)
        kv = self.init_cache(b, max_len)
        x, ys, _ = self._backbone(
            params, tokens, q_pos, k_pos, caches=self._cache_tuple(kv),
            write_at=0
        )
        logits = common.logits_matmul(x[:, -1], self._out_embed(params))
        return logits, self._rebuild(kv, ys, jnp.full((b,), s, jnp.int32))

    def prefill_chunk(self, params, tokens, offset, cache, *, first=False,
                      lens=None, extras=None):
        """One fixed-size chunk of a chunked prefill: write this chunk's k/v
        at ``offset`` (traced — chunks never recompile; scalar or per-row)
        and attend causally.  The first chunk attends its fresh k/v
        (identical numerics to the one-shot ``prefill``; an int8 cache
        calibrates its scales here, over only the valid tokens — pad must
        not widen them); continuation chunks attend the cache prefix.
        ``lens`` (b,) counts each row's valid tokens — 0 parks a row, whose
        garbage k/v lands only at positions its own future writes overwrite
        before anything attends them.  Returns per-row last-valid-token
        logits (b, V) and the cache."""
        b, s = tokens.shape
        offset = jnp.asarray(offset, jnp.int32)
        q_pos = (offset[:, None] if offset.ndim else offset) + \
            jnp.arange(s, dtype=jnp.int32)
        k_pos = jnp.arange(cache.capacity, dtype=jnp.int32)
        x, ys, _ = self._backbone(
            params, tokens, q_pos, k_pos, caches=self._cache_tuple(cache),
            write_at=offset, chunked=not first,
            calib_len=s if lens is None else lens
        )
        logits = common.logits_matmul(dcache.pick_last(x, lens),
                                      self._out_embed(params))
        new_pos = jnp.broadcast_to(
            offset + (s if lens is None else jnp.asarray(lens, jnp.int32)),
            (b,))
        return logits, self._rebuild(cache, ys, new_pos)

    def decode_step(self, params, tokens, pos, cache, extras=None):
        b = tokens.shape[0]
        pos = jnp.asarray(pos, jnp.int32)
        # scalar pos: lockstep decode; (b,) pos: continuous batching — each
        # row queries and writes at its own depth (per-row kernel lanes)
        q_pos = pos[:, None] if pos.ndim else jnp.full((1,), pos, jnp.int32)
        k_pos = jnp.arange(cache.capacity, dtype=jnp.int32)
        x, ys, _ = self._backbone(
            params, tokens, q_pos, k_pos, caches=self._cache_tuple(cache),
            write_at=pos
        )
        logits = common.logits_matmul(x[:, -1], self._out_embed(params))
        return logits, self._rebuild(cache, ys,
                                     jnp.broadcast_to(pos + 1, (b,)))
