"""DecodeCache: per-row decode-cache state for every model family.

One abstraction replaces five bespoke cache pytrees.  Each *layout* is a
registered-dataclass pytree whose single source of truth for "how deep is
this row's context" is a per-row ``(b,)`` vector — the same vectors the
flash-decode kernel consumes as its per-row ``q_offset``/``kv_len`` SMEM
lanes (``repro.kernels.flash_attention``).  A model family composes its
cache from these layouts (a layout instance, or a dict of them); the
serving engine stays layout-generic by talking only to the module-level
composite helpers (:func:`slot`, :func:`set_slot`, :func:`reset_row`,
:func:`set_row_valid`, :func:`lengths`, and the fault-recovery pair
:func:`snapshot_row`/:func:`restore_row`).

Layouts
-------

``LinearKV``
    Dense/vlm/encdec self-attention: contiguous k/v slabs with the batch at
    a layout-static axis (dense/encdec stack layers in front, vlm stacks
    (superblock, self-layer)), an optional int8 quantization (per-(batch,
    kv-head) f32 scales ride alongside), and the per-row ``pos`` write
    cursor.  Absorbs the old ``common.cache_write``.

``RingKV``
    Hybrid's windowed decode buffer: capacity ``C = min(max_len, window)``
    slots, position ``p`` lives in slot ``p % C``.  Per-row absolute write
    cursors; the wrap-aware mapping into the kernel's per-row vectors is
    :meth:`RingKV.attend_lens` (``kv_len = min(pos + 1, C)``) with
    ``q_offset = pos`` — an unwrapped row is a contiguous prefix, a wrapped
    row attends all ``C`` slots (softmax is permutation-invariant and every
    live slot is inside the window, so slot order never matters).  The jnp
    oracle route gets true positions from :meth:`RingKV.slot_positions`.

``CrossKV``
    Encoder-decoder cross-attention k/v (and the vlm image k/v): written
    once per request at its first prefill chunk, frozen afterwards —
    position-free, so only row isolation matters.

``StateCarry``
    ssm/hybrid recurrent state (conv tails, LRU hidden state, SSD state):
    position-free, with a per-row ``valid`` mask so rows reset
    independently when a slot is reused — decode updates select
    ``where(valid, new, old)`` via :func:`masked_rows`, prefill chunks mask
    by their per-row valid-token counts instead.

Mutation helpers (:func:`linear_write`, :func:`ring_write`,
:func:`masked_rows`, :func:`conv_tail`, :func:`pick_last`) are the ONLY
sanctioned ways a model family touches cache storage — a layering test
greps the family sources for raw ``dynamic_update_slice_in_dim`` / ad-hoc
cache dicts (``tests/test_cache.py``).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def _register(cls, data_fields, meta_fields=()):
    jax.tree_util.register_dataclass(cls, list(data_fields), list(meta_fields))
    return cls


def _slice_axis(a, axis, i):
    return jax.lax.slice_in_dim(a, i, i + 1, axis=axis)


def _set_axis(a, axis, i, sub):
    idx = tuple(slice(None) if ax != axis else slice(i, i + 1)
                for ax in range(a.ndim))
    return a.at[idx].set(sub)


# ---------------------------------------------------------------------------
# layouts
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LinearKV:
    """Contiguous k/v slabs; batch at static axis ``b_axis``, sequence at
    ``b_axis + 1``.  ``pos`` (b,) int32 is each row's context depth == its
    next write position."""

    k: jax.Array                      # (*lead, b, S, kvh, hd)
    v: jax.Array
    pos: jax.Array                    # (b,) int32
    k_scale: Optional[jax.Array]      # (*lead, b, kvh) f32 | None
    v_scale: Optional[jax.Array]
    b_axis: int

    @classmethod
    def create(cls, lead, batch, seq, kv_heads, head_dim, dtype, *,
               quantized=False, b_axis=None):
        shape = tuple(lead) + (batch, seq, kv_heads, head_dim)
        b_axis = len(lead) if b_axis is None else b_axis
        # two distinct buffers: donated jits reject aliased pytree leaves
        def scale():
            return (jnp.ones(tuple(lead) + (batch, kv_heads), jnp.float32)
                    if quantized else None)
        return cls(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   pos=jnp.zeros((batch,), jnp.int32),
                   k_scale=scale(), v_scale=scale(), b_axis=b_axis)

    @property
    def capacity(self) -> int:
        return self.k.shape[self.b_axis + 1]

    @property
    def quantized(self) -> bool:
        return self.k_scale is not None

    def replace(self, **kw) -> "LinearKV":
        return dataclasses.replace(self, **kw)

    def slot(self, i: int) -> "LinearKV":
        sc = (None if self.k_scale is None
              else _slice_axis(self.k_scale, self.b_axis, i))
        vc = (None if self.v_scale is None
              else _slice_axis(self.v_scale, self.b_axis, i))
        return self.replace(k=_slice_axis(self.k, self.b_axis, i),
                            v=_slice_axis(self.v, self.b_axis, i),
                            pos=self.pos[i:i + 1], k_scale=sc, v_scale=vc)

    def set_slot(self, i: int, sub: "LinearKV") -> "LinearKV":
        ks = (None if self.k_scale is None
              else _set_axis(self.k_scale, self.b_axis, i, sub.k_scale))
        vs = (None if self.v_scale is None
              else _set_axis(self.v_scale, self.b_axis, i, sub.v_scale))
        return self.replace(k=_set_axis(self.k, self.b_axis, i, sub.k),
                            v=_set_axis(self.v, self.b_axis, i, sub.v),
                            pos=self.pos.at[i:i + 1].set(sub.pos),
                            k_scale=ks, v_scale=vs)

    def reset_row(self, i: int) -> "LinearKV":
        # slabs need no zeroing — writes are position-exact and nothing
        # attends past the row's pos (the per-row kv_len masks it)
        return self.replace(pos=self.pos.at[i].set(0))

    def lengths(self) -> jax.Array:
        return self.pos


_register(LinearKV, ("k", "v", "pos", "k_scale", "v_scale"), ("b_axis",))


@dataclass(frozen=True)
class RingKV:
    """Windowed ring buffer: capacity ``C`` slots at axis ``b_axis + 1``,
    absolute position ``p`` in slot ``p % C``.  ``pos`` (b,) int32 counts
    tokens written per row (the absolute cursor)."""

    k: jax.Array                      # (*lead, b, C, kvh, hd)
    v: jax.Array
    pos: jax.Array                    # (b,) int32
    b_axis: int

    @classmethod
    def create(cls, lead, batch, capacity, kv_heads, head_dim, dtype, *,
               b_axis=None):
        shape = tuple(lead) + (batch, capacity, kv_heads, head_dim)
        b_axis = len(lead) if b_axis is None else b_axis
        return cls(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   pos=jnp.zeros((batch,), jnp.int32), b_axis=b_axis)

    @property
    def capacity(self) -> int:
        return self.k.shape[self.b_axis + 1]

    def replace(self, **kw) -> "RingKV":
        return dataclasses.replace(self, **kw)

    def slot(self, i: int) -> "RingKV":
        return self.replace(k=_slice_axis(self.k, self.b_axis, i),
                            v=_slice_axis(self.v, self.b_axis, i),
                            pos=self.pos[i:i + 1])

    def set_slot(self, i: int, sub: "RingKV") -> "RingKV":
        return self.replace(k=_set_axis(self.k, self.b_axis, i, sub.k),
                            v=_set_axis(self.v, self.b_axis, i, sub.v),
                            pos=self.pos.at[i:i + 1].set(sub.pos))

    def reset_row(self, i: int) -> "RingKV":
        return self.replace(pos=self.pos.at[i].set(0))

    def lengths(self) -> jax.Array:
        return jnp.minimum(self.pos, self.capacity)

    # -- the per-row wrap-aware mapping into the flash kernel's SMEM lanes --
    def attend_lens(self, pos) -> jax.Array:
        """``kv_len`` vector for a decode at absolute positions ``pos``
        (b,): ``min(pos + 1, C)`` slots are live.  With ``q_offset = pos``
        and causal masking the kernel attends exactly those — an unwrapped
        row's contiguous prefix, or (wrapped) the whole ring, every slot of
        which is inside the window since ``C <= window``."""
        return jnp.minimum(jnp.asarray(pos, jnp.int32) + 1, self.capacity)

    def slot_positions(self, pos) -> jax.Array:
        """True position held by each slot, per row: slot ``j`` holds
        ``pos - ((pos - j) mod C)``; never-written slots surface a huge
        positive position so causal masking kills them.  (b, C) int32 —
        the jnp oracle's key positions."""
        c = self.capacity
        pos = jnp.asarray(pos, jnp.int32).reshape(-1, 1)
        idx = jnp.arange(c, dtype=jnp.int32)[None, :]
        ring_pos = pos - ((pos - idx) % c)
        return jnp.where(ring_pos >= 0, ring_pos, jnp.int32(1 << 30))


_register(RingKV, ("k", "v", "pos"), ("b_axis",))


@dataclass(frozen=True)
class CrossKV:
    """Cross-attention k/v, written at a request's first prefill chunk and
    frozen for its lifetime.  Position-free."""

    k: jax.Array                      # (*lead, b, E, kvh, hd)
    v: jax.Array
    b_axis: int

    @classmethod
    def create(cls, lead, batch, enc, kv_heads, head_dim, dtype, *,
               b_axis=None):
        shape = tuple(lead) + (batch, enc, kv_heads, head_dim)
        b_axis = len(lead) if b_axis is None else b_axis
        return cls(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   b_axis=b_axis)

    def replace(self, **kw) -> "CrossKV":
        return dataclasses.replace(self, **kw)

    def slot(self, i: int) -> "CrossKV":
        return self.replace(k=_slice_axis(self.k, self.b_axis, i),
                            v=_slice_axis(self.v, self.b_axis, i))

    def set_slot(self, i: int, sub: "CrossKV") -> "CrossKV":
        return self.replace(k=_set_axis(self.k, self.b_axis, i, sub.k),
                            v=_set_axis(self.v, self.b_axis, i, sub.v))

    def reset_row(self, i: int) -> "CrossKV":
        return self  # overwritten wholesale at the next first chunk

    def lengths(self):
        return None


_register(CrossKV, ("k", "v"), ("b_axis",))


@dataclass(frozen=True)
class StateCarry:
    """Recurrent per-row state: a dict of arrays, every one with batch at
    axis 1 (layer-stacked in front).  ``valid`` (b,) bool marks rows whose
    carried state belongs to a live decode — a reused slot resets its row
    independently of its neighbours."""

    states: dict
    valid: jax.Array                  # (b,) bool

    @classmethod
    def create(cls, states: dict):
        batch = next(iter(states.values())).shape[1]
        return cls(states=dict(states),
                   valid=jnp.ones((batch,), bool))

    def replace(self, **kw) -> "StateCarry":
        return dataclasses.replace(self, **kw)

    def slot(self, i: int) -> "StateCarry":
        return StateCarry(
            states={k: _slice_axis(a, 1, i) for k, a in self.states.items()},
            valid=self.valid[i:i + 1])

    def set_slot(self, i: int, sub: "StateCarry") -> "StateCarry":
        return StateCarry(
            states={k: _set_axis(a, 1, i, sub.states[k])
                    for k, a in self.states.items()},
            valid=self.valid.at[i:i + 1].set(sub.valid))

    def reset_row(self, i: int) -> "StateCarry":
        return StateCarry(
            states={k: _set_axis(a, 1, i, jnp.zeros_like(_slice_axis(a, 1, i)))
                    for k, a in self.states.items()},
            valid=self.valid.at[i].set(False))

    def set_row_valid(self, i: int, flag: bool) -> "StateCarry":
        return self.replace(valid=self.valid.at[i].set(bool(flag)))

    def lengths(self):
        return None


_register(StateCarry, ("states", "valid"))

_LAYOUTS = (LinearKV, RingKV, CrossKV, StateCarry)


# ---------------------------------------------------------------------------
# composite helpers: a cache is a layout, or a dict/tuple of caches
# ---------------------------------------------------------------------------

def _map_layouts(cache, fn):
    if isinstance(cache, _LAYOUTS):
        return fn(cache)
    if isinstance(cache, dict):
        return {k: _map_layouts(v, fn) for k, v in cache.items()}
    if isinstance(cache, (tuple, list)):
        return type(cache)(_map_layouts(v, fn) for v in cache)
    raise TypeError(f"not a DecodeCache composite: {type(cache)!r}")


def slot(cache, i: int):
    """The b=1 slice of every layout for engine slot ``i``."""
    return _map_layouts(cache, lambda lo: lo.slot(i))


def set_slot(cache, i: int, sub):
    """Write a b=1 sub-cache back into slot ``i`` of every layout."""
    if isinstance(cache, _LAYOUTS):
        return cache.set_slot(i, sub)
    if isinstance(cache, dict):
        return {k: set_slot(v, i, sub[k]) for k, v in cache.items()}
    return type(cache)(set_slot(v, i, s) for v, s in zip(cache, sub))


def reset_row(cache, i: int):
    """Row ``i`` leaves its request: cursors to zero, recurrent state
    zeroed and invalidated.  The engine calls this at admission so a reused
    slot never sees its predecessor's state."""
    return _map_layouts(cache, lambda lo: lo.reset_row(i))


def set_row_valid(cache, i: int, flag: bool):
    """Flip row ``i``'s recurrent-state validity (StateCarry layouts only;
    positional layouts are already row-exact via their cursors)."""
    return _map_layouts(
        cache,
        lambda lo: lo.set_row_valid(i, flag) if isinstance(lo, StateCarry)
        else lo)


def snapshot_row(cache, i: int):
    """Host-staged copy of slot ``i`` across every layout: the b=1 pytree
    slice of the whole composite with numpy leaves, so the snapshot costs
    no device memory and survives the engine's donated-buffer launches.
    Taken on a token-count cadence by the serving engine, it is the resume
    point for BOTH fault recovery (a poisoned row) and pressure eviction —
    restore plus a short greedy token replay instead of whole-residency
    recompute.  Restore with :func:`restore_row`, into the same or a
    DIFFERENT slot (row slices carry no slot identity)."""
    return jax.device_get(slot(cache, i))


def restore_row(cache, i: int, snap):
    """Write a :func:`snapshot_row` back into slot ``i``: slabs, positional
    cursors, int8 scales, frozen cross-KV, recurrent state and its validity
    all land, so the row resumes exactly at its snapshot point."""
    return set_slot(cache, i, snap)


def snapshot_compatible(cache, snap) -> None:
    """The cross-replica portability gate: validate that a host-staged
    :func:`snapshot_row` can restore into ``cache`` — same composite
    structure, every leaf matching the cache's own b=1 row slice in shape
    and dtype.  Row slices carry no slot or replica identity, so a
    snapshot taken on one replica restores into ANY replica built from the
    same serving config; a mismatch (different ``max_len``, window,
    quantization, or family) must fail loudly here, not corrupt a row.
    Raises ``ValueError`` naming the first mismatch; cost is abstract-only
    (``eval_shape`` — no device work)."""
    ref = jax.eval_shape(lambda: slot(cache, 0))
    ref_leaves, ref_def = jax.tree_util.tree_flatten(ref)
    snap_leaves, snap_def = jax.tree_util.tree_flatten(snap)
    if ref_def != snap_def:
        raise ValueError(
            f"snapshot layout mismatch: cache rows are {ref_def}, "
            f"snapshot is {snap_def}")
    for r, s in zip(ref_leaves, snap_leaves):
        if tuple(r.shape) != tuple(np.shape(s)):
            raise ValueError(
                f"snapshot row shape mismatch: cache row leaf {r.shape} "
                f"vs snapshot leaf {np.shape(s)}")
        if jnp.dtype(r.dtype) != jnp.dtype(np.asarray(s).dtype):
            raise ValueError(
                f"snapshot row dtype mismatch: cache row leaf {r.dtype} "
                f"vs snapshot leaf {np.asarray(s).dtype}")


def lengths(cache):
    """Per-row context depth: the elementwise max over every positional
    layout's lengths, or None if the cache is position-free (pure state
    carry)."""
    found = []
    _map_layouts(cache, lambda lo: found.append(lo.lengths()) or lo)
    vecs = [x for x in found if x is not None]
    if not vecs:
        return None
    out = vecs[0]
    for x in vecs[1:]:
        out = jnp.maximum(out, x)
    return out


# ---------------------------------------------------------------------------
# mutation helpers — the only sanctioned cache writes
# ---------------------------------------------------------------------------

def linear_write(slab, new, write_at):
    """Write ``new`` (b, s, kvh, hd) into a linear slab at sequence offset
    ``write_at`` — a scalar (lockstep: every row at the same depth) or a
    (b,) vector (continuous batching: each slot at its own depth, one
    vmapped per-row dynamic slice)."""
    if jnp.ndim(write_at) == 0:
        return jax.lax.dynamic_update_slice_in_dim(slab, new, write_at,
                                                   axis=1)
    return jax.vmap(
        lambda c, n, w: jax.lax.dynamic_update_slice_in_dim(c, n, w, axis=0)
    )(slab, new, write_at)


def ring_write(slab, new, write_at):
    """Write ``new`` (b, s, kvh, hd) into a ring slab (b, C, kvh, hd) at
    absolute offset ``write_at`` (scalar or (b,)): position ``p`` lands in
    slot ``p % C``.  When ``s >= C`` only the last ``C`` tokens survive
    (unique slots — no scatter-order hazard)."""
    b, s = new.shape[:2]
    c = slab.shape[1]
    wa = jnp.broadcast_to(jnp.asarray(write_at, jnp.int32), (b,))
    if s >= c:
        new = new[:, s - c:]
        wa = wa + (s - c)
        s = c
    idx = (wa[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]) % c
    return jax.vmap(lambda sl, n, ix: sl.at[ix].set(n))(slab, new, idx)


def masked_rows(mask, new, old, axis: int = 0):
    """Per-row select ``where(mask, new, old)`` with ``mask`` (b,) aligned
    to the batch ``axis`` and broadcast over every other dim — the
    row-isolation update discipline (decode: mask = valid; prefill chunk:
    mask = chunk_lens > 0; frozen CrossKV slabs: mask = first-chunk rows)."""
    mask = jnp.asarray(mask)
    shape = [1] * new.ndim
    shape[axis] = mask.shape[0]
    return jnp.where(mask.reshape(shape), new, old)


def conv_tail(xp, lens, width: int):
    """Per-row causal-conv state after consuming ``lens`` valid tokens of a
    padded chunk.  ``xp`` (b, s + width, dim) is the conv input with the
    previous state prepended; row ``r``'s new state is
    ``xp[r, lens[r] : lens[r] + width]`` — ``lens = 0`` returns the old
    state untouched, ``lens = s`` the true tail."""
    xp_len = xp.shape[1]
    lens = jnp.broadcast_to(jnp.asarray(lens, jnp.int32), (xp.shape[0],))
    lens = jnp.clip(lens, 0, xp_len - width)
    return jax.vmap(
        lambda row, l: jax.lax.dynamic_slice_in_dim(row, l, width, axis=0)
    )(xp, lens)


def pick_last(x, lens):
    """Each row's features at its last valid token: ``x`` (b, s, d),
    ``lens`` (b,) valid counts (None = the full chunk) -> (b, d)."""
    if lens is None:
        return x[:, -1]
    row = jnp.clip(jnp.asarray(lens, jnp.int32) - 1, 0, x.shape[1] - 1)
    return jnp.take_along_axis(x, row[:, None, None], axis=1)[:, 0]


def token_mask(lens, s: int):
    """(b, s) bool valid-token mask from per-row counts; None = all valid
    (the lockstep full-sequence path takes no masking at all)."""
    if lens is None:
        return None
    lens = jnp.asarray(lens, jnp.int32)
    return jnp.arange(s, dtype=jnp.int32)[None, :] < lens[:, None]
