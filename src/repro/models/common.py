"""Shared model blocks: norms, RoPE, attention (GQA / sliding-window / cross),
gated MLP, embeddings, and the blockwise (BP-structured) attention used for
long sequences.

The blockwise attention is the paper's BP computation made concrete: the
online-softmax combine ``(m,l,acc) ⊕ (m',l',acc')`` is associative, so the
KV-block loop is exactly a BP reduce (down-pass = per-block partial attention,
up-pass = combine).  On TPU the per-block body becomes the Pallas kernel in
``repro.kernels.flash_attention``; here we express the same computation with
``jax.lax.scan`` so XLA sees a small, memory-bounded loop.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.sharding_hints import constrain  # noqa: F401  (re-exported)


# ---------------------------------------------------------------------------
# initialization helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in init."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_lookup(embed, tokens):
    """Token embedding lookup with the table replicated over the tensor axis.

    The table is (vocab@tp, d@fsdp) for the logits matmul; for the *lookup*
    an all-gather of the small table over tp (~MBs) beats the all-reduce of
    the (b, s, d) activation (~GBs) that GSPMD otherwise emits for a
    vocab-sharded gather.  PWS-planner rule: steal the cheap fork.
    """
    table = constrain(embed, None, "*")  # replicate vocab over tp; keep fsdp dim
    return table[tokens]


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x, weight, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., s, hd/2)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _mask_bias(q_pos, k_pos, *, causal: bool, window: Optional[int]):
    """(q, k) additive bias from positions; built from iota (no big
    constants).  ``q_pos`` may be batched (b, sq) — the continuous-batching
    decode where each row sits at its own position — giving a (b, q, k)
    bias."""
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    ok = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), dtype=bool)
    if causal:
        ok &= kp <= qp
    if window is not None:
        ok &= kp > (qp - window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def repeat_kv(k, n_rep: int):
    """(b, t, kvh, hd) -> (b, t, kvh*n_rep, hd)"""
    if n_rep == 1:
        return k
    b, t, kvh, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, t, kvh, n_rep, hd)).reshape(b, t, kvh * n_rep, hd)


def attention_dense(q, k, v, q_pos, k_pos, *, causal=True, window=None, softmax_scale=None):
    """Reference attention, materializes (q, k) scores.  Used for short
    sequences and decode (q_len == 1).

    GQA keeps k/v at their native head count: q folds its per-group heads
    into the einsum instead of repeating the (potentially cache-sized) k/v
    tensors — on every decode step the cache streams through once, ungrown.
    bf16 operands + f32 accumulation (native MXU semantics): no f32 copy of
    the cache is ever materialized either."""
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    n_rep = h // kvh
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(b, sq, kvh, n_rep, hd)  # head h = kv_head * n_rep + rep
    scores = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k,
                        preferred_element_type=jnp.float32) * scale
    scores = constrain(scores, "batch", "kv_heads", "*", "*", "*")
    bias = _mask_bias(q_pos, k_pos, causal=causal, window=window)
    # batched (b, q, k) bias (per-row decode positions) aligns on batch
    bias = bias[:, None, None] if bias.ndim == 3 else bias[None, None, None]
    scores = scores + bias
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, sq, h, hd)
    return constrain(out.astype(q.dtype), "batch", "*", "heads", "*")


def _blockwise_fwd_inner(qs, ks, vs, qp, kp, window, *, causal, scale, n_rep):
    """Forward pass over (nq, b, h, qb, hd) q-blocks and (nk, b, kvh, kb, hd)
    kv-blocks.  Returns (out_blocks, lse_blocks) — the BP down-pass with the
    online-softmax combine as the up-pass."""
    nq, b, h, q_block, hd = qs.shape
    kvh = h // n_rep

    def per_qblock(carry, qi):
        qb, qpb = qi

        def per_kvblock(state, ki):
            m, l, acc = state
            kb, vb, kpb = ki
            kb_len = kb.shape[2]
            if n_rep > 1:
                # native KV heads: fold q's per-group heads into the einsum
                # (head h = kv_head * n_rep + rep) — the oracle shares the
                # kernel's no-copy discipline, no block ever repeats
                qg = qb.reshape(b, kvh, n_rep, q_block, hd)
                s = jnp.einsum("bgrqd,bgkd->bgrqk", qg, kb,
                               preferred_element_type=jnp.float32,
                               ).reshape(b, h, q_block, kb_len) * scale
            else:
                s = jnp.einsum("bhqd,bhkd->bhqk", qb, kb,
                               preferred_element_type=jnp.float32) * scale
            s = constrain(s, "batch", "heads", "*", "*")
            s = s + _mask_bias(qpb, kpb, causal=causal, window=window)[None, None]
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            correction = jnp.exp(m - m_new)
            l_new = l * correction + jnp.sum(p, axis=-1)
            if n_rep > 1:
                pg = p.reshape(b, kvh, n_rep, q_block, kb_len)
                pv = jnp.einsum("bgrqk,bgkd->bgrqd", pg.astype(vb.dtype), vb,
                                preferred_element_type=jnp.float32,
                                ).reshape(b, h, q_block, hd)
            else:
                pv = jnp.einsum("bhqk,bhkd->bhqd", p.astype(vb.dtype), vb,
                                preferred_element_type=jnp.float32)
            acc_new = acc * correction[..., None] + pv
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((b, h, q_block), NEG_INF, jnp.float32),
            jnp.zeros((b, h, q_block), jnp.float32),
            jnp.zeros((b, h, q_block, hd), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(per_kvblock, init, (ks, vs, kp))
        l_safe = jnp.maximum(l, 1e-30)
        out = acc / l_safe[..., None]
        lse = m + jnp.log(l_safe)
        return carry, (out, lse)

    _, (outs, lses) = jax.lax.scan(per_qblock, None, (qs, qp))
    return outs, lses  # (nq, b, h, qb, hd), (nq, b, h, qb)


def _make_blockwise(causal: bool, scale: float, q_block: int, kv_block: int,
                    n_rep: int):
    """Build a custom-VJP blockwise attention for fixed static config.
    The (possibly traced) sliding window is a real argument — never closed
    over — so per-layer windows can flow through ``lax.scan``.

    The backward recomputes P per block (flash-attention backward), so no
    O(sq*sk) tensor is ever saved — the paper's limited-access discipline
    applied to autodiff residuals.
    """

    @jax.custom_vjp
    def fa(qs, ks, vs, qp, kp, warr):
        outs, _ = _blockwise_fwd_inner(qs, ks, vs, qp, kp, warr[0], causal=causal,
                                       scale=scale, n_rep=n_rep)
        return outs

    def fa_fwd(qs, ks, vs, qp, kp, warr):
        outs, lses = _blockwise_fwd_inner(qs, ks, vs, qp, kp, warr[0], causal=causal,
                                          scale=scale, n_rep=n_rep)
        return outs, (qs, ks, vs, qp, kp, warr, outs, lses)

    def fa_bwd(res, g):
        qs, ks, vs, qp, kp, warr, outs, lses = res
        window = warr[0]
        nq, b, h, q_block, hd = qs.shape
        nk = ks.shape[0]
        kvh = ks.shape[2]
        # D = rowsum(dO * O)
        delta = jnp.sum(g.astype(jnp.float32) * outs.astype(jnp.float32), axis=-1)

        def per_qblock(carry, xs):
            dk_acc, dv_acc = carry  # (nk, b, kvh, kb, hd) fp32
            qb, qpb, ob, lseb, gb, db = xs

            def per_kvblock(dq, ki):
                (kb, vb, kpb, dk_a, dv_a) = ki
                kb_len = kb.shape[2]
                gf = gb
                if n_rep > 1:
                    # grouped einsums at the native KV head count: the r axis
                    # contracts away in the dk/dv products, so the group sum
                    # happens inside the einsum — no repeated block, no
                    # post-hoc reshape-sum
                    qg = qb.reshape(b, kvh, n_rep, q_block, hd)
                    gg = gf.reshape(b, kvh, n_rep, q_block, hd)
                    s = jnp.einsum("bgrqd,bgkd->bgrqk", qg, kb,
                                   preferred_element_type=jnp.float32,
                                   ).reshape(b, h, q_block, kb_len) * scale
                    s = s + _mask_bias(qpb, kpb, causal=causal,
                                       window=window)[None, None]
                    p = jnp.exp(s - lseb[..., None])  # (b,h,qb,kb) f32
                    pg = p.reshape(b, kvh, n_rep, q_block, kb_len)
                    dv_blk = jnp.einsum("bgrqk,bgrqd->bgkd", pg.astype(gf.dtype),
                                        gg, preferred_element_type=jnp.float32)
                    dp = jnp.einsum("bgrqd,bgkd->bgrqk", gg, vb,
                                    preferred_element_type=jnp.float32,
                                    ).reshape(b, h, q_block, kb_len)
                    ds = p * (dp - db[..., None]) * scale
                    dsg = ds.reshape(b, kvh, n_rep, q_block, kb_len)
                    dq = dq + jnp.einsum("bgrqk,bgkd->bgrqd",
                                         dsg.astype(kb.dtype), kb,
                                         preferred_element_type=jnp.float32,
                                         ).reshape(b, h, q_block, hd)
                    dk_blk = jnp.einsum("bgrqk,bgrqd->bgkd",
                                        dsg.astype(qb.dtype), qg,
                                        preferred_element_type=jnp.float32)
                else:
                    s = jnp.einsum("bhqd,bhkd->bhqk", qb, kb,
                                   preferred_element_type=jnp.float32) * scale
                    s = s + _mask_bias(qpb, kpb, causal=causal,
                                       window=window)[None, None]
                    p = jnp.exp(s - lseb[..., None])  # (b,h,qb,kb) f32
                    dv_blk = jnp.einsum("bhqk,bhqd->bhkd", p.astype(gf.dtype),
                                        gf, preferred_element_type=jnp.float32)
                    dp = jnp.einsum("bhqd,bhkd->bhqk", gf, vb,
                                    preferred_element_type=jnp.float32)
                    ds = p * (dp - db[..., None]) * scale
                    dq = dq + jnp.einsum("bhqk,bhkd->bhqd", ds.astype(kb.dtype),
                                         kb, preferred_element_type=jnp.float32)
                    dk_blk = jnp.einsum("bhqk,bhqd->bhkd", ds.astype(qb.dtype),
                                        qb, preferred_element_type=jnp.float32)
                return dq, (dk_a + dk_blk, dv_a + dv_blk)

            dq0 = jnp.zeros((b, h, q_block, hd), jnp.float32)
            dq, (dk_new, dv_new) = jax.lax.scan(
                per_kvblock, dq0, (ks, vs, kp, dk_acc, dv_acc))
            return (dk_new, dv_new), dq

        dk0 = jnp.zeros((nk,) + ks.shape[1:], jnp.float32)
        dv0 = jnp.zeros((nk,) + vs.shape[1:], jnp.float32)
        (dk, dv), dqs = jax.lax.scan(per_qblock, (dk0, dv0),
                                     (qs, qp, outs, lses, g, delta))
        return (dqs.astype(qs.dtype), dk.astype(ks.dtype), dv.astype(vs.dtype),
                None, None, None)

    fa.defvjp(fa_fwd, fa_bwd)
    return fa


def attention_blockwise(
    q,
    k,
    v,
    q_pos,
    k_pos,
    *,
    causal=True,
    window=None,
    softmax_scale=None,
    q_block: int = 512,
    kv_block: int = 1024,
):
    """Flash-style blockwise attention as a BP computation over KV blocks
    with a flash backward (custom VJP — O(block^2) working set, never
    O(sq*sk)).

    The online-softmax combine ``(m,l,acc)`` is associative: the KV-block
    loop is a BP reduce (paper Def. 3.2), and the Pallas kernel twin is
    ``repro.kernels.flash_attention``.
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    kvh = k.shape[2]
    n_rep = h // kvh
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(hd)

    q_block = min(q_block, sq)
    kv_block = min(kv_block, sk)
    assert sq % q_block == 0 and sk % kv_block == 0, (sq, q_block, sk, kv_block)
    nq, nk = sq // q_block, sk // kv_block

    qs = q.reshape(b, nq, q_block, h, hd).transpose(1, 0, 3, 2, 4)
    ks = k.reshape(b, nk, kv_block, kvh, hd).transpose(1, 0, 3, 2, 4)
    vs = v.reshape(b, nk, kv_block, kvh, hd).transpose(1, 0, 3, 2, 4)
    qp = q_pos.reshape(nq, q_block)
    kp = k_pos.reshape(nk, kv_block)

    warr = jnp.asarray([(1 << 30) if window is None else window], jnp.int32)
    fa = _make_blockwise(causal, scale, q_block, kv_block, n_rep)
    outs = fa(qs, ks, vs, qp, kp, warr)  # (nq, b, h, qb, hd)
    out = outs.transpose(1, 0, 3, 2, 4).reshape(b, sq, h, hd).astype(q.dtype)
    return constrain(out, "batch", "*", "heads", "*")


def attention_banded_local(q, k, v, q_pos, k_pos, *, window: int, softmax_scale=None):
    """Beyond-paper optimized sliding-window attention: attend each query
    block only to its own and the previous KV block (exact when
    ``window <= block``).  This is the paper's O(1)-block-sharing principle:
    each task (query block) touches O(1) KV blocks.

    Compute drops from O(s^2) to O(s * 2*block).
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    assert sq == sk, "banded local attention expects self-attention"
    block = max(window, 128)
    if sq % block != 0 or sq <= 2 * block:
        return attention_blockwise(q, k, v, q_pos, k_pos, causal=True, window=window,
                                   softmax_scale=softmax_scale)
    kvh = k.shape[2]
    n_rep = h // kvh
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(hd)
    nb = sq // block

    k = repeat_kv(k, n_rep)
    v = repeat_kv(v, n_rep)
    qs = q.reshape(b, nb, block, h, hd)
    ks = k.reshape(b, nb, block, h, hd)
    vs = v.reshape(b, nb, block, h, hd)
    # previous block (block 0's "previous" is zeros and fully masked)
    ks_prev = jnp.concatenate([jnp.zeros_like(ks[:, :1]), ks[:, :-1]], axis=1)
    vs_prev = jnp.concatenate([jnp.zeros_like(vs[:, :1]), vs[:, :-1]], axis=1)
    kcat = jnp.concatenate([ks_prev, ks], axis=2)  # (b, nb, 2*block, h, hd)
    vcat = jnp.concatenate([vs_prev, vs], axis=2)

    qp = q_pos.reshape(nb, block)
    kp_local = q_pos.reshape(nb, block)
    kp_prev = jnp.concatenate([jnp.full((1, block), -10**9, jnp.int32), kp_local[:-1]], axis=0)
    kp_cat = jnp.concatenate([kp_prev, kp_local], axis=1)  # (nb, 2*block)

    s = jnp.einsum("bnqhd,bnkhd->bnhqk", qs.astype(jnp.float32), kcat.astype(jnp.float32)) * scale
    ok = (kp_cat[:, None, :] <= qp[:, :, None]) & (kp_cat[:, None, :] > qp[:, :, None] - window)
    s = s + jnp.where(ok, 0.0, NEG_INF)[None, :, None]  # (b, nb, h, q, k)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bnhqk,bnkhd->bnqhd", p.astype(vcat.dtype), vcat)
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def attention_blockwise_triangular(q, k, v, q_pos, k_pos, *, window=None,
                                   softmax_scale=None, q_block: int = 512):
    """Beyond-paper optimization: causal blockwise attention that SKIPS
    fully-masked (future) KV blocks by unrolling the q-block loop — q block i
    attends KV blocks 0..i only.  Halves attention compute and the
    scores-tensor traffic vs the masked full grid.  Exact (the skipped blocks
    contribute nothing)."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    assert sq == sk, "triangular path is for self-attention"
    kvh = k.shape[2]
    n_rep = h // kvh
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(hd)
    q_block = min(q_block, sq)
    assert sq % q_block == 0
    nq = sq // q_block

    qs = q.reshape(b, nq, q_block, h, hd).transpose(1, 0, 3, 2, 4)
    ks = k.reshape(b, nq, q_block, kvh, hd).transpose(1, 0, 3, 2, 4)
    vs = v.reshape(b, nq, q_block, kvh, hd).transpose(1, 0, 3, 2, 4)
    qp = q_pos.reshape(nq, q_block)
    kp = k_pos.reshape(nq, q_block)
    warr = jnp.asarray([(1 << 30) if window is None else window], jnp.int32)

    fa = _make_blockwise(True, scale, q_block, q_block, n_rep)
    outs = []
    for i in range(nq):
        o = fa(qs[i : i + 1], ks[: i + 1], vs[: i + 1], qp[i : i + 1], kp[: i + 1],
               warr)
        outs.append(o)
    out = jnp.concatenate(outs, 0).transpose(1, 0, 3, 2, 4).reshape(b, sq, h, hd)
    return constrain(out.astype(q.dtype), "batch", "*", "heads", "*")


def _attention_via_kernel(q, k, v, q_pos, k_pos, *, causal, window, q_block,
                          kv_block, k_scale=None, v_scale=None, kv_len=None):
    """Adapter onto the registry's flash-attention Pallas kernel: fold heads
    into batch (batch-major, head = kv_head * n_rep + rep), dispatch, unfold.
    K/V stay at their NATIVE head count — the kernel's kv ``index_map``
    routes each query head's grid steps into its group's KV row, so the
    cache-sized ``repeat_kv`` copy the old adapter paid per call never
    exists; the kernel's rep-aware transposed grid group-sums dk/dv.

    CONTRACT: with ``kv_len=None``, positions must be contiguous ranges
    (q row i at ``q_pos[0] + i``, key j at ``k_pos[0] + j``) whenever they
    matter (causal or windowed masking) — linear DecodeCache layouts and
    fresh self-attention satisfy this, and the offset/length vectors are
    derived from the positions.  An explicit ``kv_len`` (scalar or per-row
    (b,)) overrides the derivation for layouts whose keys are raw cache
    slots starting at 0 — ``RingKV``'s wrap-aware mapping passes
    ``q_offset = pos`` and ``kv_len = min(pos + 1, C)`` so a wrapped row
    attends its whole ring (slot order is a softmax permutation) and an
    unwrapped row its contiguous prefix; ``kv_len == 0`` rows emit exact
    zeros (the kernel's ``l_safe`` guard).  Under causal masking KV blocks
    past ``kv_len`` are skipped instead of computed-then-masked.
    ``k_scale``/``v_scale`` — per (batch, kv_head) f32, paired with an int8
    k/v — ride to the kernel's in-block dequant."""
    from repro.kernels import registry

    b, sq, h, hd = q.shape
    sk = k.shape[1]
    kvh = k.shape[2]

    def fold(x):
        return x.transpose(0, 2, 1, 3).reshape(b * x.shape[2], x.shape[1], hd)

    def fold_scale(s):
        return None if s is None else jnp.asarray(s, jnp.float32).reshape(b * kvh)

    if kv_len is not None:
        # explicit valid-key counts: keys are raw cache slots (base 0), so
        # the query offset is the position itself (per-row or scalar)
        q_offset = (q_pos[:, 0] if q_pos.ndim == 2 else q_pos[:1]).astype(jnp.int32)
        kv_len = jnp.asarray(kv_len, jnp.int32)
        if kv_len.ndim == 0:
            kv_len = kv_len[None]
    elif sq == sk:
        q_offset = kv_len = None  # zero-offset self-attention: static path
    elif q_pos.ndim == 2:
        # per-row decode: each batch lane carries its own position, so the
        # kernel gets (b,) offset/length vectors (SMEM; batch-major fold
        # means lane = bh // h, matching the kernel's rows contract)
        q_offset = (q_pos[:, 0] - k_pos[0]).astype(jnp.int32)
        kv_len = (jnp.minimum(q_offset + sq, sk).astype(jnp.int32)
                  if causal else None)
    else:
        q_offset = (q_pos[0] - k_pos[0]).astype(jnp.int32)
        kv_len = jnp.minimum(q_offset + sq, sk) if causal else None

    # forward overrides only when divisor-exact; else the per-shape plan wins
    qb = q_block if (q_block and sq % min(q_block, sq) == 0) else None
    kb = kv_block if (kv_block and sk % min(kv_block, sk) == 0) else None
    kwargs = {}
    if kvh != h:
        kwargs["n_heads"] = h
    if k_scale is not None:
        kwargs["k_scale"] = fold_scale(k_scale)
        kwargs["v_scale"] = fold_scale(v_scale)
    out = registry.dispatch(
        "attention", fold(q), fold(k), fold(v), causal=causal,
        window=0 if window is None else int(window),
        q_offset=q_offset, kv_len=kv_len, impl="pallas",
        q_block=qb, kv_block=kb, **kwargs,
    )
    return out.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)


def attention(q, k, v, q_pos, k_pos, *, causal=True, window=None, softmax_scale=None,
              use_banded_local: bool = False, block_threshold: int = 2048,
              q_block: int = 512, kv_block: int = 1024,
              causal_block_skip: bool = False, k_scale=None, v_scale=None,
              kv_len=None):
    """Dispatch: dense for small/decode, blockwise for long, banded for local,
    triangular for causal long self-attention when block-skip is enabled.

    The backend is the ambient execution policy's call, resolved through
    ``registry.resolve`` — no per-call knob.  "jnp" keeps the XLA paths,
    whose blockwise variant carries the flash custom VJP; "pallas" routes
    the registry's flash kernel, which covers cached decode (query offset +
    KV valid-length) and registers its own recomputation backward, so both
    training and the serving prefill/decode loop share one resolution.
    ``resolve`` consults the kernel's capability metadata (``has_vjp``; the
    ``needs`` gate rejects custom softmax scales and traced scan-carried
    windows — the kernel's window/causal are static kwargs).  The kernel
    route assumes contiguous position ranges UNLESS the caller passes an
    explicit ``kv_len`` — the ``RingKV`` layout does, mapping its wrapped
    rows onto the kernel's per-row vectors (see
    :func:`_attention_via_kernel`), which is what lets the windowed decode
    cache ride the same kernel as every linear layout; cross-attention with
    meaningless positions is fine too since it is non-causal/unwindowed.
    The jnp routes ignore ``kv_len`` — their masks come from the true
    positions (``RingKV.slot_positions``).  Banded-local is a model-level
    algorithm choice, so it stays on its jnp path regardless of the
    resolved backend.

    ``k_scale``/``v_scale`` — per-(batch, kv_head) f32, paired with an int8
    ``k``/``v`` — reach the kernel's in-block dequant on the pallas route;
    every jnp route dequantizes up front (cache-sized f32 copy: the oracle
    pays what the kernel avoids, which is the point of the kernel)."""
    from repro.kernels import registry

    sq, sk = q.shape[1], k.shape[1]
    impl = registry.resolve("attention", softmax_scale=softmax_scale,
                            window=window)
    if impl == "pallas" and not use_banded_local:
        return _attention_via_kernel(q, k, v, q_pos, k_pos, causal=causal,
                                     window=window, q_block=q_block,
                                     kv_block=kv_block, k_scale=k_scale,
                                     v_scale=v_scale, kv_len=kv_len)
    if k_scale is not None:
        k = (k.astype(jnp.float32) * k_scale[:, None, :, None]).astype(q.dtype)
        v = (v.astype(jnp.float32) * v_scale[:, None, :, None]).astype(q.dtype)
    if window is not None and use_banded_local and sq == sk and sq > 2 * max(window, 128):
        return attention_banded_local(q, k, v, q_pos, k_pos, window=window,
                                      softmax_scale=softmax_scale)
    if sq == 1 or (sq * sk <= block_threshold * block_threshold):
        return attention_dense(q, k, v, q_pos, k_pos, causal=causal, window=window,
                               softmax_scale=softmax_scale)
    if causal and causal_block_skip and sq == sk:
        return attention_blockwise_triangular(q, k, v, q_pos, k_pos, window=window,
                                              softmax_scale=softmax_scale,
                                              q_block=max(q_block, kv_block))
    return attention_blockwise(q, k, v, q_pos, k_pos, causal=causal, window=window,
                               softmax_scale=softmax_scale, q_block=q_block,
                               kv_block=kv_block)


# ---------------------------------------------------------------------------
# quantized KV cache (the attention kv_dtype variant)
# ---------------------------------------------------------------------------

def kv_cache_dtype(default):
    """The serving KV-cache dtype under the ambient policy: an attention
    ``kv_dtype`` variant (``--impl 'attention=pallas:kv_dtype=int8'``)
    selects the int8 cache; anything else keeps ``default``.  Returns
    ``(dtype, quantized)``."""
    from repro.kernels import policy

    name = policy.current().variant_for("attention").get("kv_dtype")
    if name in ("int8", "i8"):
        return jnp.int8, True
    return default, False


def kv_scale(x, valid=None):
    """Per-(batch, kv_head) symmetric int8 scale for a (b, s, kvh, hd) k or v
    slab: absmax / 127, floored so an all-zero head still divides cleanly.
    ``valid`` (optional, traced ok; scalar or per-row (b,)) restricts the
    absmax to the first ``valid`` sequence positions — a zero-padded prefill
    chunk must not let pad-token k/v widen the scales that the rest of the
    request will quantize with."""
    ax = jnp.abs(x.astype(jnp.float32))
    if valid is not None:
        v = jnp.asarray(valid)
        v = v[:, None, None, None] if v.ndim == 1 else v
        ok = jnp.arange(x.shape[1])[None, :, None, None] < v
        ax = jnp.where(ok, ax, 0.0)
    amax = jnp.max(ax, axis=(1, 3))  # (b, kvh)
    return jnp.maximum(amax / 127.0, 1e-8)


def quantize_kv(x, scale):
    """Quantize a (b, s, kvh, hd) slab to int8 with the per-(b, kvh)
    ``scale`` (see :func:`kv_scale`); round-to-nearest, clipped."""
    q = jnp.round(x.astype(jnp.float32) / scale[:, None, :, None])
    return jnp.clip(q, -127, 127).astype(jnp.int8)


# ---------------------------------------------------------------------------
# MLP / projections through the kernel registry
# ---------------------------------------------------------------------------

def project(x, w):
    """x: (..., d) @ w: (d, f) -> (..., f), backend resolved by the ambient
    execution policy.  The pallas route folds the leading dims and
    dispatches the registry's matmul — planner-tiled, backend-selected
    (classical/Strassen by the costmodel envelopes), autotune-overlaid,
    differentiable via the kernel's custom VJP; jnp keeps the XLA einsum."""
    from repro.kernels import registry

    if registry.resolve("matmul") == "pallas":
        lead = x.shape[:-1]
        out = registry.dispatch("matmul", x.reshape(-1, x.shape[-1]), w,
                                impl="pallas")
        return out.reshape(*lead, w.shape[-1])
    return jnp.einsum("...d,df->...f", x, w)


def qkv_project(x, wq, wk, wv):
    """The attention-block input projections, policy-fusable: under a
    ``qkv_fused`` variant on the matmul op (``--impl
    'matmul=pallas:qkv_fused=true'`` or ``RunOptions.fused_qkv``) the three
    per-block projections collapse into ONE ``(d, hq+hk+hv)`` matmul over
    concatenated weights — one planned kernel launch streaming ``x`` once
    instead of three launches streaming it three times — then split back.
    Without the variant: three :func:`project` calls (each still
    policy-routed).  Numerically identical either way (same contractions,
    independent output columns)."""
    from repro.kernels import policy

    if policy.current().variant_for("matmul").get("qkv_fused"):
        w = jnp.concatenate([wq, wk, wv], axis=1)
        fused = project(x, w)
        q, k, v = jnp.split(fused, [wq.shape[1], wq.shape[1] + wk.shape[1]],
                            axis=-1)
        return q, k, v
    return project(x, wq), project(x, wk), project(x, wv)


def attn_out_project(o, wo):
    """Attention epilogue: (b, s, h, hd) heads -> (b, s, d) through the
    output projection, without materializing the flattened (b*s, h*hd)
    reshape as a separate tensor on the jnp route.  The pallas route folds
    the head axes into the registry matmul's contraction dim (one planned
    kernel, the fold is free — same buffer); the jnp route contracts the
    head axes directly in the einsum."""
    b, s, h, hd = o.shape
    from repro.kernels import registry

    if registry.resolve("matmul") == "pallas":
        out = registry.dispatch("matmul", o.reshape(b * s, h * hd),
                                wo.reshape(h * hd, -1), impl="pallas")
        return out.reshape(b, s, -1)
    return jnp.einsum("bshd,hdf->bsf", o, wo.reshape(h, hd, -1))


def expert_project(h, w):
    """Per-expert matmul h: (..., E, C, d) @ w: (E, d, f) -> (..., E, C, f)
    (the MoE expert FFN products).  The pallas route vmaps :func:`project`
    over the expert axis — pallas_call batching turns the expert dim into
    one more grid dimension, so each expert's slab stays a registry-planned
    kernel call; jnp keeps the batched einsum."""
    from repro.kernels import registry

    if registry.resolve("matmul") == "pallas":
        return jax.vmap(project, in_axes=(-3, 0), out_axes=-3)(h, w)
    return jnp.einsum("...ecd,edf->...ecf", h, w)


def gated_mlp(x, w_gate, w_up, w_down):
    """SwiGLU MLP; the three projections resolve their backend through the
    ambient policy (see :func:`project`)."""
    g = project(x, w_gate)
    u = project(x, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = constrain(h, *(["batch"] + ["*"] * (h.ndim - 2) + ["ffn"]))
    out = project(h, w_down)
    if out.ndim == 3:
        return constrain(out, "batch", "seq", "*")
    return constrain(out, *(["batch"] + ["*"] * (out.ndim - 1)))


def logits_matmul(h, embed_out):
    """Output-logits product h @ embed_outᵀ in fp32.  h: (..., d),
    embed_out: (V, d) -> (..., V).  The hottest serve-path matmul: the
    pallas route dispatches the registry's backend-selected kernel."""
    from repro.kernels import registry

    if registry.resolve("matmul") == "pallas":
        lead = h.shape[:-1]
        out = registry.dispatch("matmul", h.reshape(-1, h.shape[-1]),
                                embed_out.T, impl="pallas")
        return out.reshape(*lead, embed_out.shape[0]).astype(jnp.float32)
    return jnp.einsum("...d,vd->...v", h, embed_out).astype(jnp.float32)


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def chunked_softmax_xent(hidden, embed_out, labels, *, chunk: int = 512):
    """Cross-entropy computed in sequence chunks so the (tokens, vocab) logits
    tensor never materializes in full (the paper's principle of bounding the
    working set of a task; each chunk is one BP leaf).  The per-chunk logits
    matmul resolves its backend through the ambient policy (the matmul
    kernel's custom VJP keeps the pallas route differentiable under the
    chunk remat).

    hidden: (b, s, d);  embed_out: (V, d);  labels: (b, s) int32 with -100 pad.
    Returns mean loss (fp32 scalar).
    """
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    if s % chunk != 0:
        chunk = s  # fallback: single chunk
    n = s // chunk
    hs = hidden.reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(b, n, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def per_chunk(carry, xs):
        h, lab = xs
        h = constrain(h, "batch", "*", "*")
        logits = logits_matmul(h, embed_out)
        logits = constrain(logits, "batch", "*", "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        valid = lab >= 0
        safe = jnp.where(valid, lab, 0)
        picked = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        nll = jnp.where(valid, lse - picked, 0.0)
        return (carry[0] + jnp.sum(nll), carry[1] + jnp.sum(valid)), None

    (tot, cnt), _ = jax.lax.scan(per_chunk, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), (hs, ls))
    return tot / jnp.maximum(cnt, 1.0)
