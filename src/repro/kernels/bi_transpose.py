"""BI-tiled transpose Pallas kernel — the paper's MT algorithm on the MXU.

The recursive BI quadrant swap becomes: visit (bt x bt) tiles in Morton
order (the BI layout applied to the *grid schedule*), each grid step reads
tile (i, j) and writes its transpose to tile (j, i).  Every output element
written exactly once (limited access); each task touches exactly two tiles
(O(1)-block sharing — the paper's L(r) = O(1) for MT)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.hbp_matmul import _morton_ij


def _transpose_kernel(x_ref, out_ref):
    out_ref[...] = x_ref[...].T


@functools.partial(jax.jit, static_argnames=("bt", "morton", "interpret"))
def bi_transpose(x: jax.Array, *, bt: int = 128, morton: bool = True,
                 interpret: bool = True) -> jax.Array:
    """x: (m, n) -> (n, m), tile-blocked."""
    m, n = x.shape
    bt_m, bt_n = min(bt, m), min(bt, n)
    assert m % bt_m == 0 and n % bt_n == 0
    nm, nn = m // bt_m, n // bt_n

    if morton and nm == nn and (nm & (nm - 1)) == 0:
        grid = (nm * nn,)

        def in_map(g):
            i, j = _morton_ij(g)
            return (i, j)

        def out_map(g):
            i, j = _morton_ij(g)
            return (j, i)
    else:
        grid = (nm * nn,)

        def in_map(g):
            return (g // nn, g % nn)

        def out_map(g):
            return (g % nn, g // nn)

    return pl.pallas_call(
        _transpose_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bt_m, bt_n), in_map)],
        out_specs=pl.BlockSpec((bt_n, bt_m), out_map),
        out_shape=jax.ShapeDtypeStruct((n, m), x.dtype),
        interpret=interpret,
    )(x)
