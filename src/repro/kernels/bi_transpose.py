"""BI-tiled transpose Pallas kernel — the paper's MT algorithm on the MXU.

The recursive BI quadrant swap becomes: visit (bt x bt) tiles in Morton
order (``repro.kernels.morton`` — the BI layout applied to the *grid
schedule*), each grid step reads tile (i, j) and writes its transpose to
tile (j, i).  Every output element written exactly once (limited access);
each task touches exactly two tiles (O(1)-block sharing — the paper's
L(r) = O(1) for MT).

``bt=None`` (the default) plans the tile edge from the queried device via
``repro.kernels.planner``."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.morton import grid_decode


def _transpose_kernel(x_ref, out_ref):
    out_ref[...] = x_ref[...].T


@functools.partial(jax.jit, static_argnames=("bt", "morton", "interpret"))
def bi_transpose(x: jax.Array, *, bt: Optional[int] = None, morton: bool = True,
                 interpret: bool = True) -> jax.Array:
    """x: (m, n) -> (n, m), tile-blocked."""
    m, n = x.shape
    if bt is None:
        from repro.kernels import planner

        bt = planner.plan_transpose(m, n, x.dtype)["bt"]
    bt_m, bt_n = min(bt, m), min(bt, n)
    assert m % bt_m == 0 and n % bt_n == 0
    nm, nn = m // bt_m, n // bt_n

    decode = grid_decode(nm, nn, morton=morton)
    grid = (nm * nn,)

    def in_map(g):
        i, j = decode(g)
        return (i, j)

    def out_map(g):
        i, j = decode(g)
        return (j, i)

    return pl.pallas_call(
        _transpose_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bt_m, bt_n), in_map)],
        out_specs=pl.BlockSpec((bt_n, bt_m), out_map),
        out_shape=jax.ShapeDtypeStruct((n, m), x.dtype),
        interpret=interpret,
    )(x)
