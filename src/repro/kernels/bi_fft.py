"""BI-order FFT on the kernel substrate — completing the paper's trio
(scans, matrix computations, FFT) at the kernel layer.

The four-step (Bailey) factorization of a length-n DFT with n = n1 * n2:

  1. view the row as an (n1, n2) matrix A[j1, j2] = x[j1*n2 + j2];
  2. DFT each *column* (length n1):  B = W(n1) @ A;
  3. twiddle:  B[k1, j2] *= exp(-2*pi*i * k1*j2 / n);
  4. DFT each *row* (length n2):  C = B @ W(n2);
  5. read out transposed:  X[k2*n1 + k1] = C[k1, k2].

This is exactly the paper's Type 2 HBP recursion for FFT unrolled one
level: both factors are ~sqrt(n) (``planner.plan_fft``), so each small DFT
is a matrix product that fits the O(sqrt M) tile envelope, and
Q = (n/B) log_M n follows.  On the MXU the small DFTs *are* matmuls: every
O(n^1.5) flop runs through ``hbp_matmul``'s Morton-ordered Pallas grid
(complex arithmetic as four real products), with tile shapes planned from
the queried device.  The O(n) reshapes/twiddles between stages stay in XLA.

``fft_ref`` in ``repro.kernels.ref`` (``jnp.fft.fft``) is the oracle.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.hbp_matmul import hbp_matmul


def _dft_factors(n: int) -> tuple[np.ndarray, np.ndarray]:
    """Dense DFT matrix W[k, j] = exp(-2*pi*i*k*j/n) as (real, imag) f32."""
    kj = np.outer(np.arange(n), np.arange(n))
    w = np.exp(-2j * np.pi * kj / n)
    return w.real.astype(np.float32), w.imag.astype(np.float32)


def _cmatmul(ar, ai, br, bi, *, interpret: bool):
    """(ar + i*ai) @ (br + i*bi) via four Morton-ordered Pallas matmuls."""
    rr = hbp_matmul(ar, br, interpret=interpret) - hbp_matmul(
        ai, bi, interpret=interpret)
    ri = hbp_matmul(ar, bi, interpret=interpret) + hbp_matmul(
        ai, br, interpret=interpret)
    return rr, ri


@functools.partial(jax.jit, static_argnames=("n1", "interpret"))
def bi_fft(x: jax.Array, *, n1: Optional[int] = None,
           interpret: bool = True) -> jax.Array:
    """DFT along the last axis.  x: (rows, n) real or complex, n a power of
    two.  Returns complex64 (rows, n)."""
    rows, n = x.shape
    if n & (n - 1) != 0:
        raise ValueError(f"bi_fft needs a power-of-two length, got {n}")
    if n1 is None:
        from repro.kernels import planner

        n1 = planner.plan_fft(n)["n1"]
    n1 = max(min(n1, n), 1)
    while n % n1 != 0:
        n1 //= 2
    n2 = n // n1

    xr = jnp.real(x).astype(jnp.float32)
    xi = jnp.imag(x).astype(jnp.float32) if jnp.iscomplexobj(x) else jnp.zeros_like(xr)
    if n1 == 1 or n2 == 1:  # degenerate split: one dense DFT matmul
        wr, wi = _dft_factors(n)
        yr, yi = _cmatmul(xr, xi, jnp.asarray(wr).T, jnp.asarray(wi).T,
                          interpret=interpret)
        return jax.lax.complex(yr, yi)

    # step 1: (rows, n) -> columns-major fold (n1, rows*n2)
    ar = xr.reshape(rows, n1, n2).transpose(1, 0, 2).reshape(n1, rows * n2)
    ai = xi.reshape(rows, n1, n2).transpose(1, 0, 2).reshape(n1, rows * n2)

    # step 2: column DFTs — B = W(n1) @ A
    w1r, w1i = _dft_factors(n1)
    br, bi_ = _cmatmul(jnp.asarray(w1r), jnp.asarray(w1i), ar, ai,
                       interpret=interpret)

    # step 3: twiddle by exp(-2*pi*i * k1*j2 / n), broadcast over rows
    k1j2 = np.outer(np.arange(n1), np.arange(n2)).astype(np.float64)
    tw = np.exp(-2j * np.pi * k1j2 / n)
    twr = jnp.asarray(tw.real.astype(np.float32))[:, None, :]
    twi = jnp.asarray(tw.imag.astype(np.float32))[:, None, :]
    b3r = br.reshape(n1, rows, n2)
    b3i = bi_.reshape(n1, rows, n2)
    cr = b3r * twr - b3i * twi
    ci = b3r * twi + b3i * twr

    # step 4: row DFTs — C = B @ W(n2)  (W symmetric, so right-multiply)
    w2r, w2i = _dft_factors(n2)
    dr, di = _cmatmul(cr.reshape(n1 * rows, n2), ci.reshape(n1 * rows, n2),
                      jnp.asarray(w2r), jnp.asarray(w2i), interpret=interpret)

    # step 5: transposed readout X[r, k2*n1 + k1] = C[k1, r, k2]
    outr = dr.reshape(n1, rows, n2).transpose(1, 2, 0).reshape(rows, n)
    outi = di.reshape(n1, rows, n2).transpose(1, 2, 0).reshape(rows, n)
    return jax.lax.complex(outr, outi)
