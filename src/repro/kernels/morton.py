"""Shared Morton (bit-interleaved / BI) grid-order machinery for the Pallas
kernels — the paper's §3.2 BI layout applied to *grid schedules*.

``repro.core.layouts`` holds the numpy codec used by the simulator; this
module is its kernel-side twin: the same bit tricks written against plain
integer arithmetic so they work on Python ints *and* traced Pallas grid
indices (``pl.program_id``).  ``tests/test_kernel_substrate.py``
cross-validates the two implementations.

The exported policy point is :func:`grid_decode`: every kernel that walks a
2-D tile grid through a flattened index asks it for the decode function, so
the BI schedule (and its row-major fallback for non-square / non-power-of-two
grids) lives in exactly one place.
"""
from __future__ import annotations

from typing import Callable, Tuple

_EVEN_MASK = 0x55555555


def part1by1(x):
    """Spread the low 16 bits of ``x`` to even bit positions."""
    x = (x | (x << 8)) & 0x00FF00FF
    x = (x | (x << 4)) & 0x0F0F0F0F
    x = (x | (x << 2)) & 0x33333333
    x = (x | (x << 1)) & _EVEN_MASK
    return x


def compact1by1(x):
    """Inverse of :func:`part1by1`: gather even bit positions to the low 16."""
    x = x & _EVEN_MASK
    x = (x | (x >> 1)) & 0x33333333
    x = (x | (x >> 2)) & 0x0F0F0F0F
    x = (x | (x >> 4)) & 0x00FF00FF
    x = (x | (x >> 8)) & 0x0000FFFF
    return x


def morton_of(i, j):
    """Morton (Z-order) code of tile (i, j): row bits to odd positions, column
    bits to even — the recursive quadrant order (TL, TR, BL, BR)."""
    return (part1by1(i) << 1) | part1by1(j)


def morton_ij(g) -> Tuple[object, object]:
    """Decode Morton code ``g`` -> (i, j).  Works on traced integers."""
    return compact1by1(g >> 1), compact1by1(g)


def supports_morton(nm: int, nn: int) -> bool:
    """BI order is defined for square power-of-two tile grids (the paper's
    recursive quadrant decomposition); everything else falls back row-major."""
    return nm == nn and nm > 0 and (nm & (nm - 1)) == 0


def grid_decode(nm: int, nn: int, *, morton: bool = True) -> Callable:
    """Decode function for a flattened ``(nm * nn,)`` tile grid.

    Returns ``decode(g) -> (i, j)`` visiting tiles in Morton (BI) order when
    the grid is square power-of-two and ``morton`` is requested, else in
    row-major order.  Successive BI steps share one of the two coordinates
    half the time at every scale — the O(1)-block-sharing argument of §3.2
    carried to the tile schedule.
    """
    if morton and supports_morton(nm, nn):
        return morton_ij

    def rowmajor(g):
        return g // nn, g % nn

    return rowmajor
