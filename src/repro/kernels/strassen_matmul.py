"""Strassen-schedule matmul over the HBP-tiled Pallas leaf kernel.

The paper's Type-2 HBP exemplar (Depth-n-MM / Strassen, §3.2: W = n^2.807,
Q = n^lam / (B M^(lam/2 - 1))) realized on the kernel substrate: the
7-product quadrant recursion runs at trace time, reusing the
``_STRASSEN_LHS/RHS/OUT`` combination structure the simulator programs in
``repro.core.algorithms`` (the simulator's MA trees do not track signs —
the numeric kernel adds the matching sign tables below), down to a
planner-chosen ``cutoff`` edge.  Beneath the cutoff each leaf dispatches to
the Morton-ordered ``hbp_matmul`` tile kernel with ``out_dtype=float32``,
so the f32 accumulator survives the whole combination tree: operand
combinations (A11 + A22 etc.) are formed as fused jnp adds feeding the leaf
``pallas_call``s, quadrant combines stay in f32, and only the final result
rounds to the input dtype.

``matmul`` is the registry's dispatch entry point: it resolves the
planner's ``backend`` field ("classical" | "strassen"), and registers a
custom VJP (dA = g Bᵀ, dB = Aᵀ g, each re-planned for its own — possibly
crossover-flipped — shape), so model matmuls can route through the kernels
under autodiff.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.algorithms import _STRASSEN_LHS, _STRASSEN_OUT, _STRASSEN_RHS
from repro.kernels.hbp_matmul import hbp_matmul

# Signs for the shared index structure (quadrants 0..3 = 11, 12, 21, 22;
# products 0..6 = Strassen's M1..M7): M6 = (A21 - A11)(B11 + B12) etc.
# ``tests/test_strassen.py`` cross-validates the signed combination against
# the textbook recursion in ``core.algorithms_jax.strassen``.
_LHS_SIGNS = ((1, 1), (1, 1), (1,), (1,), (1, 1), (1, -1), (1, -1))
_RHS_SIGNS = ((1, 1), (1,), (1, -1), (1, -1), (1,), (1, 1), (1, 1))
_OUT_SIGNS = ((1, 1, -1, 1), (1, 1), (1, 1), (1, -1, 1, 1))


def _combo(parts, idxs, signs, out_dtype):
    """Signed sum of quadrants/products: accumulate in f32, emit ``out_dtype``
    (for operand combinations that is the input dtype — one rounding right
    before the leaf's own f32-accumulating dot)."""
    if len(idxs) == 1:
        r = parts[idxs[0]]
        return r if r.dtype == out_dtype else r.astype(out_dtype)
    acc = parts[idxs[0]].astype(jnp.float32)
    for ix, s in zip(idxs[1:], signs[1:]):
        q = parts[ix].astype(jnp.float32)
        acc = acc + q if s > 0 else acc - q
    return acc.astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("cutoff", "bm", "bn", "bk",
                                             "morton", "interpret"))
def strassen_matmul(a: jax.Array, b: jax.Array, *,
                    cutoff: Optional[int] = None, bm: Optional[int] = None,
                    bn: Optional[int] = None, bk: Optional[int] = None,
                    morton: bool = True, interpret: bool = True) -> jax.Array:
    """C = A @ B via the Strassen quadrant recursion, classical tiled leaves.

    Ineligible shapes (non-square, or nothing to halve above the cutoff)
    fall straight through to ``hbp_matmul``; tile overrides reach the
    leaves, where ragged leaf edges snap them to divisors.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    from repro.kernels import planner

    if cutoff is None:
        cutoff = planner.strassen_cutoff(a.dtype)
    cutoff = max(int(cutoff), 1)
    if not (m == k == n and n % 2 == 0 and n > cutoff):
        return hbp_matmul(a, b, bm=bm, bn=bn, bk=bk, morton=morton,
                          interpret=interpret)

    dtype = a.dtype
    leaf = functools.partial(hbp_matmul, bm=bm, bn=bn, bk=bk, morton=morton,
                             interpret=interpret, out_dtype=jnp.float32)

    def rec(x, y, edge):
        if edge <= cutoff or edge % 2:
            return leaf(x, y)
        h = edge // 2
        xq = (x[:h, :h], x[:h, h:], x[h:, :h], x[h:, h:])
        yq = (y[:h, :h], y[:h, h:], y[h:, :h], y[h:, h:])
        prods = [rec(_combo(xq, li, ls, dtype), _combo(yq, ri, rs, dtype), h)
                 for li, ls, ri, rs in zip(_STRASSEN_LHS, _LHS_SIGNS,
                                           _STRASSEN_RHS, _RHS_SIGNS)]
        cq = [_combo(prods, oi, os_, jnp.float32)
              for oi, os_ in zip(_STRASSEN_OUT, _OUT_SIGNS)]
        return jnp.concatenate(
            [jnp.concatenate([cq[0], cq[1]], axis=1),
             jnp.concatenate([cq[2], cq[3]], axis=1)], axis=0)

    return rec(a, b, n).astype(dtype)


def _run(a, b, backend, cutoff, bm, bn, bk, morton, interpret):
    """Resolve the backend (None = ask the planner) and run the variant."""
    if backend is None:
        from repro.kernels import planner

        plan = planner.plan_matmul(a.shape[0], a.shape[1], b.shape[1], a.dtype)
        backend = plan["backend"]
        if cutoff is None:
            cutoff = plan.get("cutoff")
    if backend == "strassen":
        return strassen_matmul(a, b, cutoff=cutoff, bm=bm, bn=bn, bk=bk,
                               morton=morton, interpret=interpret)
    return hbp_matmul(a, b, bm=bm, bn=bn, bk=bk, morton=morton,
                      interpret=interpret)


@functools.lru_cache(maxsize=None)
def _vjp_matmul(backend, cutoff, bm, bn, bk, morton, interpret):
    """custom-VJP wrapper per static config: the forward runs the selected
    variant; the backward's two products re-enter ``_run`` with
    ``backend=None`` so each gradient matmul gets its *own* planner verdict
    (g Bᵀ and Aᵀ g may sit on the other side of the crossover)."""

    @jax.custom_vjp
    def f(a, b):
        return _run(a, b, backend, cutoff, bm, bn, bk, morton, interpret)

    def fwd(a, b):
        return _run(a, b, backend, cutoff, bm, bn, bk, morton, interpret), (a, b)

    def bwd(res, g):
        a, b = res
        da = _run(g, b.T, None, None, None, None, None, True, interpret)
        db = _run(a.T, g, None, None, None, None, None, True, interpret)
        return da.astype(a.dtype), db.astype(b.dtype)

    f.defvjp(fwd, bwd)
    return f


def matmul(a: jax.Array, b: jax.Array, *, backend: Optional[str] = None,
           cutoff: Optional[int] = None, bm: Optional[int] = None,
           bn: Optional[int] = None, bk: Optional[int] = None,
           morton: bool = True, interpret: bool = True) -> jax.Array:
    """Backend-dispatching matmul (the registry's ``matmul`` Pallas entry):
    ``backend`` None asks the planner; "classical" runs ``hbp_matmul``,
    "strassen" the quadrant recursion.  Differentiable (custom VJP)."""
    if backend not in (None, "classical", "strassen"):
        raise ValueError(f"unknown matmul backend {backend!r}; expected "
                         "'classical' or 'strassen'")
    return _vjp_matmul(backend, cutoff, bm, bn, bk, morton, interpret)(a, b)
