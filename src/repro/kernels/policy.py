"""Ambient execution policy: the one place backend/variant/autotune
decisions live.

The paper's scheduler is resource-oblivious because *policy* (where a task
runs) is decided in one place, never threaded through the computation dag —
the companion analyses (Cole–Ramachandran's RWS/false-sharing paper,
"Bounding Cache Miss Costs … Under General Schedulers") likewise separate
the schedule policy from the computation.  This module carries that
division of labor into kernel dispatch: model code never names a backend;
it asks ``registry.resolve``/``registry.dispatch``, which consult the
*ambient* :class:`ExecutionPolicy`.

An ``ExecutionPolicy`` is a frozen value object holding

  * ``impl``        — per-op backend map (``{"attention": "pallas",
    "*": "auto"}``); the ``"*"`` wildcard covers every op without its own
    entry, and the implicit default is ``"auto"`` (ask the registry:
    Pallas where it compiles natively, the jnp path elsewhere);
  * ``variants``    — per-op variant-knob overrides merged into dispatch
    under explicit call-site kwargs (e.g. ``{"matmul": {"backend":
    "classical"}}``);
  * ``autotune``    — measured-plan mode (``off`` | ``replay`` |
    ``search``), consulted by ``repro.kernels.autotune.mode``;
  * ``interpret``   — force (or forbid) Pallas interpret mode; ``None``
    lets dispatch pick (interpret exactly where native compilation is
    unsupported);
  * ``strict_tiles``— raise instead of warning when tile overrides are
    dropped on the oracle path;
  * ``reason``      — free-text provenance for scoped overrides (the
    ring-buffer pin records *why* it routes around the kernel).

Policies layer on a context stack (a ``contextvars.ContextVar``, so scopes
are thread- and async-isolated and trace-time safe under ``jax.jit`` —
resolution happens while tracing, and a compiled function replays the
decision baked at trace time):

    base:   ambient()   — assembled from the environment
                          (``REPRO_IMPL``, ``REPRO_STRICT_TILES``,
                          ``REPRO_INTERPRET``; ``REPRO_AUTOTUNE`` is
                          consulted by ``autotune.mode`` below the
                          launcher's pin, see :func:`ambient`)
    pinned: install()   — the launcher-resolved process policy
                          (``--impl`` on serve/train/dryrun)
    scoped: apply()/pin() — ``with``-blocks deriving from ``current()``

``RunOptions.attention_impl`` / ``matmul_impl`` / ``autotune`` survive as a
deprecated compat shim: :func:`from_run_options` turns the non-default
fields into scope updates that ``models.base.Model`` applies around its
public entry points, so the old knobs produce identical dispatch decisions
to the equivalent explicit policy.
"""
from __future__ import annotations

import contextlib
import functools
import os
from contextvars import ContextVar
from dataclasses import dataclass, field, replace
from types import MappingProxyType
from typing import Callable, Mapping, Optional

IMPLS = ("auto", "jnp", "ref", "pallas")
_AUTOTUNE_MODES = ("off", "replay", "search")


def _frozen_map(d: Optional[Mapping]) -> Mapping:
    return MappingProxyType(dict(d or {}))


@dataclass(frozen=True)
class ExecutionPolicy:
    """Every dispatch-time decision, as one immutable value.  Build
    variations with :meth:`with_` (functional update) and activate them
    with :func:`apply` / :func:`install`."""

    impl: Mapping[str, str] = field(default_factory=dict)
    variants: Mapping[str, Mapping] = field(default_factory=dict)
    autotune: Optional[str] = None
    interpret: Optional[bool] = None
    strict_tiles: bool = False
    reason: Optional[str] = None

    def __post_init__(self):
        object.__setattr__(self, "impl", _frozen_map(self.impl))
        object.__setattr__(
            self, "variants",
            _frozen_map({k: _frozen_map(v) for k, v in dict(self.variants).items()}))
        # op names validate against the registry (runtime import — the
        # registry imports this module at load): a typo'd entry in a
        # programmatic apply()/pin() would otherwise match nothing and
        # silently leave the op on its ambient backend
        from repro.kernels import registry

        known = set(registry.names()) | {"*"}
        for op, backend in self.impl.items():
            if op not in known:
                raise ValueError(f"unknown op {op!r} in impl map; "
                                 f"registered: {sorted(known)}")
            if backend not in IMPLS:
                raise ValueError(
                    f"unknown impl {backend!r} for op {op!r}; expected one of {IMPLS}")
        for op in self.variants:
            if op not in known:
                raise ValueError(f"unknown op {op!r} in variants map; "
                                 f"registered: {sorted(known)}")
        if self.autotune is not None and self.autotune not in _AUTOTUNE_MODES:
            raise ValueError(f"unknown autotune mode {self.autotune!r}; "
                             f"expected one of {_AUTOTUNE_MODES}")

    # -- queries -----------------------------------------------------------
    def impl_for(self, op: str) -> str:
        """The op's backend under this policy: its own entry, else the
        ``"*"`` wildcard, else ``"auto"``."""
        return self.impl.get(op, self.impl.get("*", "auto"))

    def variant_for(self, op: str) -> dict:
        """The op's variant-knob overrides (a fresh plain dict)."""
        return dict(self.variants.get(op, {}))

    # -- derivation --------------------------------------------------------
    def with_(self, *, impl: Optional[Mapping] = None,
              variants: Optional[Mapping] = None, **updates) -> "ExecutionPolicy":
        """Functional update.  ``impl`` and ``variants`` entries MERGE over
        the existing maps (an entry set to None deletes); scalar fields
        replace."""
        if impl is not None:
            merged = {**self.impl, **dict(impl)}
            updates["impl"] = {k: v for k, v in merged.items() if v is not None}
        if variants is not None:
            mv = dict(self.variants)
            for op, knobs in dict(variants).items():
                mv[op] = {**dict(mv.get(op, {})), **dict(knobs)}
            updates["variants"] = mv
        return replace(self, **updates)

    def describe(self) -> str:
        """One-line rendering; the impl/variant prefix round-trips through
        :func:`parse_impl_spec` (``op=backend:knob=value``; an op carrying
        variants but no impl entry prints as ``op=auto:...``, which parses
        back to the same dispatch decisions)."""
        def _fmt_knob(v):
            return str(v).lower() if isinstance(v, bool) else str(v)

        parts = []
        for op in sorted(set(self.impl) | set(self.variants)):
            entry = f"{op}={self.impl.get(op, 'auto')}"
            for knob, v in sorted(self.variants.get(op, {}).items()):
                entry += f":{knob}={_fmt_knob(v)}"
            parts.append(entry)
        for f_name in ("autotune", "interpret", "strict_tiles", "reason"):
            v = getattr(self, f_name)
            if v not in (None, False):
                parts.append(f"{f_name}={v}")
        return ",".join(parts) or "auto"


# ---------------------------------------------------------------------------
# the ambient default (environment assembly)
# ---------------------------------------------------------------------------

def _parse_knob_value(raw: str):
    """Typed variant-knob values: bools (``true``/``false``), ints, else the
    raw string (e.g. a dtype name or matmul backend)."""
    low = raw.lower()
    if low in ("true", "false"):
        return low == "true"
    try:
        return int(raw)
    except ValueError:
        return raw


def parse_impl_spec(spec: str) -> tuple[dict[str, str], dict[str, dict]]:
    """The full ``--impl`` / ``REPRO_IMPL`` grammar with variant knobs:
    ``op=backend[:knob=value]*[,op=backend...]`` — e.g.
    ``matmul=pallas:backend=classical`` or
    ``attention=pallas:kv_dtype=int8``.  Returns ``(impl, variants)`` maps
    ready for :meth:`ExecutionPolicy.with_`.  A bare backend with no ``=``
    is shorthand for the wildcard (``pallas`` == ``*=pallas``); knobs on the
    wildcard are rejected (a variant knob is per-op by construction).
    Unknown op names raise: a typo'd entry matching nothing would otherwise
    silently leave every op on ``auto`` — the experiment's 'forced' numbers
    would be the default path."""
    from repro.kernels import registry  # runtime-only: no import cycle

    known = set(registry.names()) | {"*"}
    impl: dict[str, str] = {}
    variants: dict[str, dict] = {}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        head, *knob_parts = part.split(":")
        if "=" in head:
            op, _, backend = head.partition("=")
            op, backend = op.strip(), backend.strip()
        else:
            op, backend = "*", head
        if not op:
            raise ValueError(f"bad --impl entry {part!r}: empty op name")
        if op not in known:
            raise ValueError(f"bad --impl entry {part!r}: unknown op {op!r} "
                             f"(registered: {sorted(known)})")
        if backend not in IMPLS:
            raise ValueError(f"bad --impl entry {part!r}: unknown backend "
                             f"{backend!r} (expected one of {IMPLS})")
        impl[op] = backend
        for kp in knob_parts:
            kp = kp.strip()
            if not kp:
                continue
            if op == "*":
                raise ValueError(f"bad --impl entry {part!r}: variant knobs "
                                 "need a concrete op, not the * wildcard")
            knob, sep, val = kp.partition("=")
            if not sep or not knob.strip() or not val.strip():
                raise ValueError(f"bad --impl entry {part!r}: variant knob "
                                 f"{kp!r} must be knob=value")
            variants.setdefault(op, {})[knob.strip()] = \
                _parse_knob_value(val.strip())
    return impl, variants


def parse_impl_arg(spec: str) -> dict[str, str]:
    """Back-compat impl-map-only parse of the ``--impl`` grammar (variant
    knobs are accepted and dropped; use :func:`parse_impl_spec` to keep
    them)."""
    return parse_impl_spec(spec)[0]


def _truthy(val: Optional[str]) -> bool:
    return bool(val) and val.lower() not in ("0", "false", "no", "")


# the assembled ambient, keyed on the env values it was read from so a
# monkeypatched environment (tests) re-assembles without an explicit reset
_AMBIENT_CACHE: dict[tuple, ExecutionPolicy] = {}


def ambient() -> ExecutionPolicy:
    """The base of the policy stack, assembled from the environment:
    ``REPRO_IMPL`` (impl-map grammar), ``REPRO_STRICT_TILES``,
    ``REPRO_INTERPRET``.  Memoized per env value.  ``REPRO_AUTOTUNE`` is
    deliberately NOT baked in here: the ambient ``autotune`` field stays
    None so a launcher's ``autotune.set_mode`` pin keeps outranking the
    environment (``autotune.mode`` falls back to the env itself) — only an
    explicit scope (``apply(autotune=...)`` / the RunOptions shim) sets the
    field."""
    key = tuple(os.environ.get(k) for k in (
        "REPRO_IMPL", "REPRO_STRICT_TILES", "REPRO_INTERPRET"))
    hit = _AMBIENT_CACHE.get(key)
    if hit is not None:
        return hit
    impl_env, strict_env, interp_env = key
    impl, variants = parse_impl_spec(impl_env) if impl_env else ({}, {})
    pol = ExecutionPolicy(
        impl=impl,
        variants=variants,
        strict_tiles=_truthy(strict_env),
        interpret=True if _truthy(interp_env) else None,
    )
    _AMBIENT_CACHE.clear()  # env changed: old assemblies are dead weight
    _AMBIENT_CACHE[key] = pol
    return pol


# ---------------------------------------------------------------------------
# the stack
# ---------------------------------------------------------------------------

# scoped layers (ContextVar: thread/async isolated; default empty tuple)
_STACK: ContextVar[tuple] = ContextVar("repro_policy_stack", default=())
# launcher-pinned layer between ambient and the scopes
_PROCESS: Optional[ExecutionPolicy] = None


def current() -> ExecutionPolicy:
    """The active policy: innermost ``apply`` scope, else the installed
    process policy, else the environment-assembled ambient."""
    stack = _STACK.get()
    if stack:
        return stack[-1]
    if _PROCESS is not None:
        return _PROCESS
    return ambient()


def install(pol: Optional[ExecutionPolicy]) -> None:
    """Pin (or with None clear) the process-level policy — the launcher
    hook behind ``--impl``.  Scoped ``apply`` blocks still layer on top."""
    global _PROCESS
    _PROCESS = pol


@contextlib.contextmanager
def apply(pol: Optional[ExecutionPolicy] = None, **updates):
    """Push a policy scope.  With ``pol`` push exactly that policy; with
    keyword updates derive from :func:`current` via :meth:`with_` (impl /
    variants entries merge).  Restores the previous stack on exit — nesting
    and exceptions unwind correctly, and scopes never leak across threads."""
    base = current()
    new = pol if pol is not None else base
    if updates:
        new = new.with_(**updates)
    token = _STACK.set(_STACK.get() + (new,))
    try:
        yield new
    finally:
        _STACK.reset(token)


def pin(op: str, backend: str, *, reason: str):
    """Scoped single-op override with recorded provenance — the shape a
    per-layer exception takes.  (The historical example, hybrid's
    ring-buffer decode pinning attention to jnp, is gone: the ``RingKV``
    layout maps wrapped slots onto the flash kernel's per-row
    ``q_offset``/``kv_len`` vectors, so no family pins today.)  ``reason``
    is mandatory: a pin without a why is a hardcoded string with extra
    steps."""
    return apply(impl={op: backend}, reason=reason)


def pin_if(cond, op: str, backend: str, *, reason: str):
    """:func:`pin` when ``cond`` (a static Python bool), else a no-op scope —
    for call sites whose exception only holds on some paths."""
    return pin(op, backend, reason=reason) if cond else contextlib.nullcontext()


# ---------------------------------------------------------------------------
# RunOptions compat shim
# ---------------------------------------------------------------------------

def from_run_options(opts) -> Optional[dict]:
    """Translate the deprecated ``RunOptions`` backend knobs
    (``attention_impl`` / ``matmul_impl`` / ``autotune``) into ``apply``
    updates, or None when every field is at its ambient-deferring default.
    Models wrap their public entry points with :func:`bind` over this, so
    the old knobs keep producing identical dispatch decisions."""
    updates: dict = {}
    impl = {}
    for op, fld in (("attention", "attention_impl"), ("matmul", "matmul_impl")):
        v = getattr(opts, fld, "auto")
        if v != "auto":
            impl[op] = v
    if impl:
        updates["impl"] = impl
    if getattr(opts, "fused_qkv", False):
        # one (d, 3h*hd) matmul per attention block instead of three — the
        # model layer reads this variant in ``common.qkv_project``
        updates["variants"] = {"matmul": {"qkv_fused": True}}
    tune = getattr(opts, "autotune", None)
    if tune is not None:
        updates["autotune"] = tune
    return updates or None


def bind(updates: Optional[dict], fn: Callable) -> Callable:
    """Wrap ``fn`` so each call (including jit tracing, which happens at
    Python level) runs under ``apply(**updates)``.  No-op for None."""
    if not updates:
        return fn

    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        with apply(**updates):
            return fn(*args, **kwargs)

    return wrapper
