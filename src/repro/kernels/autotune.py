"""Measured autotune layer over the resource-oblivious planner.

The planner (``repro.kernels.planner``) derives every tile shape analytically
from queried device parameters pushed through the costmodel envelopes.  The
envelopes are asymptotically right but carry constant factors (the one-third
``_budget`` slack, the 2t-deep kv panel) that real machines disagree with —
exactly the regime the companion RWS/false-sharing analysis (arXiv:1103.4142)
identifies.  This module closes the loop **without touching any kernel
signature**: kernels stay oblivious, the runtime *measures* each device's
constants and replays them.

Three pieces:

``candidates(op, *args)``
    A power-of-two ladder of tile plans around the planner's analytic point,
    filtered by the kernels' divisibility constraints and the fast-memory
    envelope (every candidate's working set fits the queried ``fast_bytes``).

``search(op, *args)``
    Times each candidate on the real kernel (compile excluded, median-of-k,
    ``block_until_ready``) and records the winner in the persisted table.

``overlay(op, args)``
    The dispatch-time hook: a tuned-table hit for the current
    ``(device_kind, op, shape_class, dtype)`` key overlays the analytic plan
    (snapped back to the actual shape's divisibility), explicit overrides
    still win.  Controlled by the mode knob:

      * ``off``    — analytic plans only (the bare-dispatch default);
      * ``replay`` — overlay persisted measurements; a cold cache is a no-op;
      * ``search`` — like replay, but a table miss on concrete (non-traced)
        arrays triggers an in-line search whose winner is persisted.

    ``REPRO_AUTOTUNE`` sets the process default; launchers call
    :func:`startup` (which resolves ``RunOptions.autotune``) and tests use
    :func:`mode_scope`.

Tables are JSON files under ``REPRO_TUNE_DIR`` (default
``~/.cache/repro/autotune``), one per sanitized ``device_kind``.  Corrupt or
unknown-format files are ignored, never fatal.

Table keys carry the op's *semantic* flags alongside the shape class
(``attention`` keys causal, window, and a decode marker — sq != sk;
``matmul`` keys the planner-selected backend), so masking regimes,
cached-decode shapes, and Strassen-vs-classical matmuls never share one
measured optimum.  Tables are also stamped with ``jax.__version__`` on
write; a table written by a different jaxlib/toolchain (or an older key
format — table versions 1 and 2) is treated as a cold cache.

Beyond tile sizes, v3 entries may tune *variant* knobs: the matmul backend
("classical" | "strassen") and its recursion ``cutoff`` (the measured
crossover can overrule the modeled one in either direction), and the
``morton`` grid-schedule flag on matmul/transpose.  On an exact-key miss,
``overlay`` interpolates: it borrows the nearest recorded shape_class for
the same ``(device_kind, op, dtype, flags)`` (snapped back to the actual
shape's divisibility) instead of going cold, logging once per borrowed key.
"""
from __future__ import annotations

import contextlib
import itertools
import json
import logging
import math
import os
import re
import statistics
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.kernels import planner

log = logging.getLogger("repro.autotune")

MODES = ("off", "replay", "search")
_DEFAULT_DIR = "~/.cache/repro/autotune"
# v2: semantic flags joined the key format; v3: matmul keys its derived
# backend flag and plans may carry variant knobs (backend/cutoff/morton).
# Older tables are ignored (cold)
_TABLE_VERSION = 3

_mode_override: Optional[str] = None
# (tune_dir, device_kind) -> entries dict; cleared by clear_cache()
_TABLE_CACHE: dict[tuple[str, str], dict] = {}

# per-op semantic kwargs folded into the table key (masking regime changes
# the measured optimum even at one shape class), with the kernel-signature
# defaults so omitted kwargs key identically to explicitly-passed defaults
_SEM_FLAGS: dict[str, dict] = {"attention": {"causal": True, "window": 0}}


# ---------------------------------------------------------------------------
# mode knob
# ---------------------------------------------------------------------------

def resolve_mode(value: Optional[str] = None) -> str:
    """Launcher-side resolution: explicit value > ``REPRO_AUTOTUNE`` >
    ``replay`` (replay on a cold cache is a no-op, so it is the safe
    startup default).  Raises on unknown modes so typos surface early."""
    m = value or os.environ.get("REPRO_AUTOTUNE") or "replay"
    if m not in MODES:
        raise ValueError(f"unknown autotune mode {m!r}; expected one of {MODES}")
    return m


def mode() -> str:
    """The active mode for bare dispatch: an autotune field set on the
    ambient :class:`~repro.kernels.policy.ExecutionPolicy` (a scoped
    ``policy.apply(autotune=...)`` or the RunOptions compat shim) wins,
    then the process override, then ``REPRO_AUTOTUNE``, else ``off``
    (analytic plans only — benchmarks and tests see the pure planner
    unless they opt in)."""
    from repro.kernels import policy

    pol = policy.current().autotune
    if pol is not None:
        return pol
    if _mode_override is not None:
        return _mode_override
    env = os.environ.get("REPRO_AUTOTUNE", "off")
    return env if env in MODES else "off"


def set_mode(m: Optional[str]) -> None:
    """Set (or with ``None`` clear) the process-wide mode override."""
    global _mode_override
    if m is not None and m not in MODES:
        raise ValueError(f"unknown autotune mode {m!r}; expected one of {MODES}")
    _mode_override = m


@contextlib.contextmanager
def mode_scope(m: Optional[str]):
    """Temporarily pin the mode (tests, benchmark arms)."""
    global _mode_override
    prev = _mode_override
    set_mode(m)
    try:
        yield
    finally:
        _mode_override = prev


def startup(m: Optional[str] = None) -> str:
    """Launcher hook (serve/train): resolve and pin the mode **process-wide**
    (every subsequent dispatch in this process replays, by design — the
    launcher owns the runtime policy), and preload the current device's
    table so the first dispatch trace pays no IO."""
    resolved = resolve_mode(m)
    set_mode(resolved)
    if resolved != "off":
        dp = planner.device_params()
        log.info("autotune %s: %d tuned plan(s) for %s",
                 resolved, len(load_table(dp.kind)), dp.kind)
    return resolved


def provenance() -> dict:
    """Tuned-table provenance for startup logs and benchmark JSON: where the
    replay table lives, whether it exists, and how many plans it holds for
    this device kind — so a serving/benchmark number can always be traced
    back to the exact tile table (or its absence) it ran with."""
    dp = planner.device_params()
    path = table_path(dp.kind)
    return {
        "mode": resolve_mode(None),
        "device_kind": dp.kind,
        "table": str(path),
        "table_exists": path.exists(),
        "tuned_plans": len(load_table(dp.kind)),
    }


# ---------------------------------------------------------------------------
# table keys
# ---------------------------------------------------------------------------

def _pow2_ceil(x: int) -> int:
    return 1 << max(int(x) - 1, 0).bit_length()


def shape_class(*args) -> str:
    """Power-of-two bucketed shape signature: nearby shapes share one table
    entry; :func:`snap_plan` restores exact divisibility at replay time."""
    return "_".join("x".join(str(_pow2_ceil(d)) for d in a.shape) or "scalar"
                    for a in args)


def sem_class(op: str, args, kwargs: Optional[dict] = None) -> str:
    """Semantic-flag suffix of the table key: the op's masking/regime kwargs
    (static Python scalars only — traced values key as ``?``), plus derived
    shape-regime markers (attention: ``decode`` when sq != sk; matmul: the
    planner-selected ``backend``, so Strassen and classical shapes never
    share a measured optimum)."""
    kwargs = kwargs or {}
    parts = []
    for flag, default in _SEM_FLAGS.get(op, {}).items():
        v = kwargs.get(flag)
        if v is None:
            v = default  # omitted == kernel default: one key per config
        if isinstance(v, (bool, int, str)):
            parts.append(f"{flag}={v}")
        else:
            parts.append(f"{flag}=?")
    if op == "attention":
        parts.append(f"decode={args[0].shape[1] != args[1].shape[1]}")
        # a quantized KV stream (int8 cache vs f32 q) re-shapes the optimum
        # (4x deeper panels) — never share an entry with the uniform-dtype
        # regime
        if args[1].dtype != args[0].dtype:
            parts.append(f"kv_dtype={jnp.dtype(args[1].dtype).name}")
    if op == "matmul":
        backend = kwargs.get("backend")
        if backend is None:
            backend = planner.plan_matmul(
                args[0].shape[0], args[0].shape[1], args[1].shape[1],
                args[0].dtype).get("backend", "classical")
        parts.append(f"backend={backend}")
    return ",".join(parts)


def entry_key(op: str, *args, kwargs: Optional[dict] = None) -> str:
    base = f"{op}|{shape_class(*args)}|{jnp.dtype(args[0].dtype).name}"
    sem = sem_class(op, args, kwargs)
    return f"{base}|{sem}" if sem else base


# ---------------------------------------------------------------------------
# persisted tables (one JSON per device_kind under REPRO_TUNE_DIR)
# ---------------------------------------------------------------------------

def tune_dir() -> Path:
    return Path(os.environ.get("REPRO_TUNE_DIR")
                or os.path.expanduser(_DEFAULT_DIR))


def table_path(kind: Optional[str] = None) -> Path:
    kind = kind or planner.device_params().kind
    safe = re.sub(r"[^A-Za-z0-9._-]+", "_", kind) or "device"
    return tune_dir() / f"{safe}.json"


def _valid_plan_value(v) -> bool:
    # tiles are positive ints; variant knobs are the morton bool and the
    # matmul backend string
    if isinstance(v, bool):
        return True
    if isinstance(v, int):
        return v > 0
    return isinstance(v, str) and v in ("classical", "strassen")


def _valid_entry(entry) -> bool:
    return (isinstance(entry, dict) and isinstance(entry.get("plan"), dict)
            and len(entry["plan"]) > 0
            and all(_valid_plan_value(v) for v in entry["plan"].values()))


def load_table(kind: Optional[str] = None) -> dict:
    """The (cached) entries dict for one device kind.  Missing, corrupt,
    unknown-format, or stale files (a different table version or a
    ``jax_version`` stamp from another jaxlib/toolchain — tuned timings do
    not survive compiler upgrades) all yield an empty table — replay
    degrades to the analytic plan, it never takes the process down."""
    kind = kind or planner.device_params().kind
    cache_key = (str(tune_dir()), kind)
    hit = _TABLE_CACHE.get(cache_key)
    if hit is not None:
        return hit
    path = table_path(kind)
    entries: dict = {}
    try:
        raw = json.loads(path.read_text())
        if not (isinstance(raw, dict) and raw.get("version") == _TABLE_VERSION
                and isinstance(raw.get("entries"), dict)):
            log.warning("autotune: ignoring table %s (unknown format)", path)
        elif raw.get("jax_version") != jax.__version__:
            log.warning("autotune: ignoring table %s (tuned under jax %s, "
                        "running %s — treating as cold)", path,
                        raw.get("jax_version"), jax.__version__)
        else:
            entries = {k: v for k, v in raw["entries"].items()
                       if _valid_entry(v)}
    except FileNotFoundError:
        pass
    except (OSError, ValueError) as exc:  # json.JSONDecodeError is ValueError
        log.warning("autotune: ignoring corrupt table %s (%s)", path, exc)
    _TABLE_CACHE[cache_key] = entries
    return entries


def save_table(kind: Optional[str] = None) -> Path:
    kind = kind or planner.device_params().kind
    entries = load_table(kind)
    path = table_path(kind)
    path.parent.mkdir(parents=True, exist_ok=True)
    payload = {"version": _TABLE_VERSION, "device_kind": kind,
               "jax_version": jax.__version__,
               "entries": {k: entries[k] for k in sorted(entries)}}
    path.write_text(json.dumps(payload, indent=2) + "\n")
    return path


def clear_cache() -> None:
    """Drop the in-process table cache (tests that redirect REPRO_TUNE_DIR)."""
    _TABLE_CACHE.clear()
    _INTERP_LOGGED.clear()


# ---------------------------------------------------------------------------
# per-op tuning metadata: which axis each tile kwarg divides, and the
# working-set model the envelope filter checks against fast_bytes
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class OpTuneInfo:
    """dims(*args) maps each tile kwarg to the axis size it must divide;
    working_set(plan, *args) models the plan's resident bytes (tile kwargs
    only).  ``variants(base, *args, dp=...)`` — when set — returns the
    non-tile alternatives to cross with the tile ladder (backend/cutoff,
    schedule flags), base (the analytic choice) first; ``variant_keys``
    names the plan keys that replay verbatim instead of snapping."""

    dims: Callable[..., dict]
    working_set: Callable[..., int]
    variants: Optional[Callable[..., list]] = None
    variant_keys: tuple = ()


def _scan_dims(x):
    return {"block": x.shape[-1]}


def _scan_ws(plan, x):
    return 4 * plan["block"] * jnp.dtype(x.dtype).itemsize


def _matmul_dims(a, b):
    return {"bm": a.shape[0], "bk": a.shape[1], "bn": b.shape[1]}


def _matmul_ws(plan, a, b):
    itemsize = jnp.dtype(a.dtype).itemsize
    bm, bn, bk = plan["bm"], plan["bn"], plan["bk"]
    return (bm * bk + bk * bn) * itemsize + 4 * bm * bn


def _transpose_dims(x):
    m, n = x.shape
    return {"bt": m if m == n else math.gcd(m, n)}


def _transpose_ws(plan, x):
    return 2 * plan["bt"] ** 2 * jnp.dtype(x.dtype).itemsize


def _attention_dims(q, k, v):
    return {"q_block": q.shape[1], "kv_block": k.shape[1]}


def _attention_ws(plan, q, k, v):
    itemsize = jnp.dtype(q.dtype).itemsize
    kv_item = jnp.dtype(k.dtype).itemsize  # quantized KV: narrower panels
    hd = q.shape[2]
    qb, kb = plan["q_block"], plan["kv_block"]
    # q rows + f32 acc rows, k/v panels (kv width), the f32 P tile,
    # (m, l) columns
    return qb * hd * (itemsize + 4) + 2 * kb * hd * kv_item \
        + 4 * qb * kb + 8 * qb


def _matmul_variants(base, a, b, dp=None):
    """Backend/schedule alternatives around the analytic matmul choice: flip
    the ``morton`` grid flag, walk the Strassen cutoff one octave each way,
    and always offer the *other* backend when the shape admits it — the
    measured crossover may sit on either side of the modeled one."""
    base = dict(base)
    out = [base]
    n = b.shape[1]
    square = a.shape[0] == a.shape[1] == n
    out.append({**base, "morton": False})
    if base.get("backend") == "strassen":
        cut = int(base.get("cutoff", n))
        for c in (cut * 2, cut // 2):
            if 64 <= c < n and c != cut:
                out.append({**base, "cutoff": c})
        out.append({"backend": "classical"})
    elif (square and n % 2 == 0 and n // 2 >= 64
          and jnp.dtype(a.dtype).name in planner._STRASSEN_DTYPES):
        # one octave under the modeled gate: leaves of n/2
        out.append({**base, "backend": "strassen", "cutoff": n // 2})
    return out


def _transpose_variants(base, x, dp=None):
    return [dict(base), {**base, "morton": False}]


def _fft_dims(x):
    return {"n1": x.shape[-1]}


def _fft_ws(plan, x):
    n = x.shape[-1]
    n1 = plan["n1"]
    n2 = max(n // max(n1, 1), 1)
    # the two dense DFT factor matrices, (real, imag) f32 each
    return 8 * (n1 * n1 + n2 * n2)


_TUNE: dict[str, OpTuneInfo] = {
    "scan": OpTuneInfo(_scan_dims, _scan_ws),
    "matmul": OpTuneInfo(_matmul_dims, _matmul_ws, variants=_matmul_variants,
                         variant_keys=("backend", "cutoff", "morton")),
    "transpose": OpTuneInfo(_transpose_dims, _transpose_ws,
                            variants=_transpose_variants,
                            variant_keys=("morton",)),
    "attention": OpTuneInfo(_attention_dims, _attention_ws),
    "fft": OpTuneInfo(_fft_dims, _fft_ws),
}


def tunable_ops() -> list[str]:
    return sorted(_TUNE)


def variant_keys(op: str) -> tuple:
    """The op's non-tile plan knobs (backend/cutoff/morton).  Dispatch feeds
    forced variant overrides back into the table lookup through this, so a
    call that pins e.g. ``backend="classical"`` replays the classical entry,
    not the one keyed by the planner's own choice."""
    info = _TUNE.get(op)
    return info.variant_keys if info else ()


# ---------------------------------------------------------------------------
# candidate generation
# ---------------------------------------------------------------------------

def snap_plan(op: str, args, plan: dict) -> dict:
    """Clamp a tuned plan (possibly recorded for a same-class neighbour
    shape) back to the kernels' divisibility constraints: each tile becomes
    the largest divisor of its axis not exceeding the tuned value; variant
    knobs (backend/cutoff/morton) replay verbatim — the kernels gate their
    own eligibility."""
    info = _TUNE[op]
    dims = info.dims(*args)
    out = {}
    for k, v in plan.items():
        if k in dims:
            out[k] = planner.divisor_tile(dims[k], int(v))
        elif k in info.variant_keys:
            out[k] = v
    return out


def candidates(op: str, *args, dp: Optional[planner.DeviceParams] = None,
               max_candidates: int = 16, span: int = 2) -> list[dict]:
    """Power-of-two ladder around the analytic plan: each tile kwarg ranges
    over factor 2**±``span`` of its planned value (snapped to divisors of its
    axis), the cross product is filtered by the fast-memory envelope, crossed
    with the op's variant alternatives (backend/cutoff, morton — see
    ``OpTuneInfo.variants``), and ranked by log-distance from the analytic
    point (each variant hop counts one octave).  The analytic plan is always
    candidate 0."""
    from repro.kernels import registry  # the layer below; lazy to stay acyclic

    spec = registry.get(op)
    info = _TUNE[op]
    dp = dp or planner.device_params()
    analytic = dict(spec.plan(*args))
    dims = info.dims(*args)
    tile_analytic = {k: v for k, v in analytic.items() if k in dims}
    variant_analytic = {k: v for k, v in analytic.items() if k not in dims}

    ladders: dict[str, list[int]] = {}
    for key, base in tile_analytic.items():
        vals = set()
        for shift in range(-span, span + 1):
            target = base << shift if shift >= 0 else max(base >> -shift, 1)
            vals.add(planner.divisor_tile(dims[key], target))
        ladders[key] = sorted(vals)

    keys = sorted(ladders)
    tile_plans = [tile_analytic]
    for combo in itertools.product(*(ladders[k] for k in keys)):
        plan = dict(zip(keys, combo))
        if plan == tile_analytic:
            continue
        if info.working_set(plan, *args) > dp.fast_bytes:
            continue
        tile_plans.append(plan)

    variants = ([dict(variant_analytic)] if info.variants is None
                else info.variants(variant_analytic, *args, dp=dp))

    def dist(p: dict) -> float:
        return sum(abs(math.log2(p[k]) - math.log2(max(tile_analytic[k], 1)))
                   for k in keys)

    def order_key(plan: dict):
        return tuple(sorted((k, str(v)) for k, v in plan.items()))

    scored, seen = [], set()
    for vi, var in enumerate(variants):
        for ti, tiles in enumerate(tile_plans):
            plan = {**tiles, **var}
            key = order_key(plan)
            if key in seen:
                continue
            seen.add(key)
            # a variant flip at the analytic tiles is the interesting
            # hypothesis (backend/cutoff/morton) — rank it right behind the
            # analytic plan, ahead of the tile fine-tuning ladder
            score = vi / 10.0 if (vi and ti == 0) else dist(tiles) + vi
            scored.append((score, plan))
    scored.sort(key=lambda t: (t[0], order_key(t[1])))
    rest = [p for _, p in scored if p != analytic]
    return [analytic] + rest[:max(max_candidates - 1, 0)]


# ---------------------------------------------------------------------------
# timing harness
# ---------------------------------------------------------------------------

def measure_us(fn, args, *, iters: int = 5, kwargs: Optional[dict] = None) -> float:
    """Median-of-``iters`` wall time in microseconds, compile excluded (one
    warm-up call runs and blocks before the clock starts)."""
    kwargs = kwargs or {}
    jax.block_until_ready(fn(*args, **kwargs))
    samples = []
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kwargs))
        samples.append((time.perf_counter() - t0) * 1e6)
    return statistics.median(samples)


def _concrete(args) -> bool:
    return not any(isinstance(a, jax.core.Tracer) for a in args)


def search(op: str, *args, iters: int = 5, max_candidates: int = 16,
           save: bool = True, **kwargs) -> dict:
    """Time the candidate ladder for one op/shape on the real kernel path
    (native where supported, interpret elsewhere), record the winner in the
    device table, and return the table entry."""
    from repro.kernels import registry

    spec = registry.get(op)
    if not _concrete(args):
        raise TypeError(f"autotune.search({op!r}) needs concrete arrays, "
                        "not tracers")
    dp = planner.device_params()
    interpret = not spec.supported()
    cands = candidates(op, *args, dp=dp, max_candidates=max_candidates)
    timed = []
    for plan in cands:
        try:
            us = measure_us(spec.pallas, args, iters=iters,
                            kwargs={**kwargs, "interpret": interpret, **plan})
        except Exception as exc:
            # the envelope filter allows working sets up to the full queried
            # fast memory (the wins live beyond the planner's 1/3 slack), so
            # a near-limit candidate may fail native compilation — skip it,
            # never abort the sweep
            log.warning("autotune %s: candidate %s failed (%s); skipping",
                        op, plan, exc)
            continue
        timed.append((us, plan))
    if not timed:
        raise RuntimeError(f"autotune {op}: every candidate failed to run")
    best_us, best = min(timed, key=lambda t: t[0])
    analytic = cands[0]
    analytic_us = next((us for us, p in timed if p == analytic), None)
    entry = {
        "plan": best,
        "us": round(best_us, 1),
        "analytic_plan": analytic,
        "analytic_us": None if analytic_us is None else round(analytic_us, 1),
        "iters": iters,
        "candidates": len(cands),
    }
    table = load_table(dp.kind)
    table[entry_key(op, *args, kwargs=kwargs)] = entry
    if save:
        save_table(dp.kind)
    return entry


# ---------------------------------------------------------------------------
# dispatch-time overlay (the integration point for registry.dispatch)
# ---------------------------------------------------------------------------

def lookup(op: str, *args, kwargs: Optional[dict] = None) -> Optional[dict]:
    """The persisted tuned plan for this op/shape-class/dtype/flags, or
    None.  ``kwargs`` are the call's semantic kwargs (they key the masking
    regime — see :func:`sem_class`)."""
    entry = load_table().get(entry_key(op, *args, kwargs=kwargs))
    return dict(entry["plan"]) if entry else None


# (tune_dir, wanted key, borrowed key) triples already logged — interpolation
# fires on every dispatch trace of a cold shape, so log once, not per trace
_INTERP_LOGGED: set[tuple] = set()


def _shape_distance(a: str, b: str) -> Optional[float]:
    """Log2 distance between two ``shape_class`` strings; None when the
    array structures differ (different arity or rank — not comparable)."""
    pa, pb = a.split("_"), b.split("_")
    if len(pa) != len(pb):
        return None
    total = 0.0
    for xa, xb in zip(pa, pb):
        da, db = xa.split("x"), xb.split("x")
        if len(da) != len(db):
            return None
        for u, v in zip(da, db):
            if u == "scalar" or v == "scalar":
                if u != v:
                    return None
                continue
            total += abs(math.log2(int(u)) - math.log2(int(v)))
    return total


def nearest_plan(op: str, *args, kwargs: Optional[dict] = None) -> Optional[dict]:
    """Cross-shape interpolation: on an exact-key miss, borrow the tuned
    plan from the *nearest* recorded shape_class with the same
    ``(op, dtype, semantic flags)`` — a neighbouring shape's measured
    constants beat the cold analytic plan.  Logs once per borrowed key."""
    table = load_table()
    if not table:
        return None
    want = entry_key(op, *args, kwargs=kwargs)
    wop, wshape, wrest = want.split("|", 2)
    best = None
    for key, entry in table.items():
        try:
            kop, kshape, krest = key.split("|", 2)
        except ValueError:
            continue
        if kop != wop or krest != wrest or kshape == wshape:
            continue
        d = _shape_distance(wshape, kshape)
        if d is None:
            continue
        if best is None or (d, key) < (best[0], best[1]):
            best = (d, key, entry)
    if best is None:
        return None
    _, key, entry = best
    tag = (str(tune_dir()), want, key)
    if tag not in _INTERP_LOGGED:
        _INTERP_LOGGED.add(tag)
        log.info("autotune: no tuned entry for %s; interpolating from "
                 "nearest recorded class %s", want, key)
    return dict(entry["plan"])


def overlay(op: str, args, *, search_kwargs: Optional[dict] = None) -> dict:
    """Tuned tile kwargs to merge over the analytic plan (empty dict when
    the mode is off, the op is untunable, or the cache is cold).  In
    ``search`` mode a miss on concrete arrays triggers an in-line search;
    otherwise a miss falls back to cross-shape interpolation
    (:func:`nearest_plan`) before going cold."""
    m = mode()
    if m == "off" or op not in _TUNE:
        return {}
    plan = lookup(op, *args, kwargs=search_kwargs)
    if plan is None and m == "search" and _concrete(args):
        plan = dict(search(op, *args, **(search_kwargs or {}))["plan"])
    if plan is None:
        plan = nearest_plan(op, *args, kwargs=search_kwargs)
    if plan is None:
        return {}
    return snap_plan(op, args, plan)
