"""BP prefix-scan Pallas kernel — the paper's PS algorithm as a TPU kernel.

Two BP passes (paper §3.2 'Scans'):
  pass 1 (down): each grid block computes its local inclusive cumsum and its
                 block total (the BP leaf reduction);
  between:       the block totals are exclusive-scanned (the up-tree — tiny,
                 done in jnp on the host program);
  pass 2 (up):   each block adds its prefix offset (the down-distribution).

Block size = the BP leaf size; VMEM tiling via BlockSpec.  Limited access:
every output element written exactly once per pass.

``block=None`` (the default) plans the leaf size from the queried device via
``repro.kernels.planner``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scan_block_kernel(x_ref, out_ref, tot_ref):
    x = x_ref[...]
    c = jnp.cumsum(x.astype(jnp.float32), axis=-1)
    out_ref[...] = c.astype(out_ref.dtype)
    tot_ref[...] = c[..., -1:].astype(tot_ref.dtype)


def _add_offset_kernel(y_ref, off_ref, out_ref):
    out_ref[...] = (y_ref[...].astype(jnp.float32)
                    + off_ref[...].astype(jnp.float32)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def bp_scan(x: jax.Array, *, block: Optional[int] = None,
            interpret: bool = True) -> jax.Array:
    """Inclusive prefix sum along the last axis.  x: (rows, n)."""
    rows, n = x.shape
    if block is None:
        from repro.kernels import planner

        block = planner.plan_scan(x.shape, x.dtype)["block"]
    block = min(block, n)
    assert n % block == 0, (n, block)
    nb = n // block

    local, totals = pl.pallas_call(
        _scan_block_kernel,
        grid=(rows, nb),
        in_specs=[pl.BlockSpec((1, block), lambda r, i: (r, i))],
        out_specs=[
            pl.BlockSpec((1, block), lambda r, i: (r, i)),
            pl.BlockSpec((1, 1), lambda r, i: (r, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, n), x.dtype),
            jax.ShapeDtypeStruct((rows, nb), jnp.float32),
        ],
        interpret=interpret,
    )(x)

    offsets = jnp.cumsum(totals, axis=-1) - totals  # exclusive scan of totals

    out = pl.pallas_call(
        _add_offset_kernel,
        grid=(rows, nb),
        in_specs=[
            pl.BlockSpec((1, block), lambda r, i: (r, i)),
            pl.BlockSpec((1, 1), lambda r, i: (r, i)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda r, i: (r, i)),
        out_shape=jax.ShapeDtypeStruct((rows, n), x.dtype),
        interpret=interpret,
    )(local, offsets)
    return out
