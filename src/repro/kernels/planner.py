"""Resource-oblivious tile planner for the Pallas kernel substrate.

The paper's HBP algorithms never see M or B; the scheduler gets sequential-
level cache costs anyway.  The kernel-layer translation: no kernel signature
carries a hard-coded tile size.  Block shapes are *derived* at trace time
from queried device parameters (fast-memory bytes, lane/sublane tiling,
dtype width) pushed through the ``repro.core.costmodel`` envelopes —
``oblivious_tile_edge`` gives the O(sqrt M) square-tile bound, and the
``seq_cache_complexity_*`` functions bound the modeled traffic of the chosen
plan.  Explicit override kwargs on ``registry.dispatch`` are preserved for
experiments.

Every plan function returns a dict of the kernel's tile kwargs, with each
tile an exact divisor of its dimension (the kernels assert divisibility) and
a multiple of the hardware (sublane, lane) tiling whenever the shape allows.
"""
from __future__ import annotations

import dataclasses
import math
import os
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import costmodel

# TPU vector-memory lane width (last-dim tiling) in elements.
LANE = 128

# Fallback fast-memory sizes when the backend exposes nothing better.
# TPU: VMEM per core (v4/v5p class).  CPU: a shared L2+L3 slice — 8 MiB also
# reproduces the seed's hand-tuned 512/1024 attention blocks exactly, so the
# planner's CPU defaults are behavior-preserving.  GPU: an L2-ish slice.
_DEFAULT_FAST_BYTES = {"tpu": 16 * 2**20, "cpu": 8 * 2**20, "gpu": 16 * 2**20}

# Block-transfer granularity B (bytes): HBM burst on TPU, cache line on CPU.
_DEFAULT_LINE_BYTES = {"tpu": 512, "cpu": 64, "gpu": 128}


@dataclass(frozen=True)
class DeviceParams:
    """The queried machine parameters the planner is oblivious *about* —
    it reads them at trace time instead of baking them into signatures."""

    platform: str
    kind: str
    fast_bytes: int  # M: fast-memory capacity the tiles must fit in
    line_bytes: int  # B: block-transfer granularity
    lane: int = LANE

    def sublane(self, dtype) -> int:
        """Second-minor tiling multiple: 8 f32 rows, packed 2x/4x for
        narrower dtypes (TPU (8, 128) native tile with sublane packing)."""
        itemsize = jnp.dtype(dtype).itemsize
        return max(32 // max(itemsize, 1), 8)


# device_params is memoized: every dispatch trace asks for it, and
# ``jax.devices()`` is not free.  The REPRO_FAST_BYTES value participates in
# the cache key so flipping the env var takes effect without a clear, but
# tests that monkeypatch deeper (fake devices, backend swaps) should call
# ``clear_device_params_cache()``.
_DP_CACHE: dict = {}


def device_params(device=None) -> DeviceParams:
    """Query the current device (memoized).  ``REPRO_FAST_BYTES`` overrides
    the fast-memory size (useful to replay a plan for a different machine);
    otherwise ``device.memory_stats()`` is consulted when the backend
    exposes it, falling back to the per-platform defaults."""
    env = os.environ.get("REPRO_FAST_BYTES")
    cache_key = (device, env)
    try:
        return _DP_CACHE[cache_key]
    except (KeyError, TypeError):  # TypeError: unhashable fake device
        pass
    dev = device if device is not None else jax.devices()[0]
    platform = getattr(dev, "platform", "cpu")
    kind = getattr(dev, "device_kind", platform)
    if env:
        fast = int(env)
    else:
        fast = (_queried_fast_bytes(dev, platform)
                or _DEFAULT_FAST_BYTES.get(platform, 8 * 2**20))
    line = _DEFAULT_LINE_BYTES.get(platform, 64)
    dp = DeviceParams(platform=platform, kind=kind, fast_bytes=fast,
                      line_bytes=line)
    try:
        _DP_CACHE[cache_key] = dp
    except TypeError:
        pass
    return dp


def clear_device_params_cache() -> None:
    """Drop memoized device queries (tests that fake devices or change the
    backend under the planner)."""
    _DP_CACHE.clear()


def _queried_fast_bytes(dev, platform: str):
    """Real fast-memory size from ``device.memory_stats()`` when the backend
    reports one.  An explicit fast-memory key wins outright; a ``bytes_limit``
    below the platform default shrinks it (the device genuinely has less),
    while HBM-sized limits are ignored — they are not the M the O(sqrt M)
    tile envelopes need."""
    try:
        stats = dev.memory_stats()
    except Exception:
        return None
    if not isinstance(stats, dict):
        return None
    for key in ("vmem_size_bytes", "fast_memory_bytes"):
        val = stats.get(key)
        if isinstance(val, (int, float)) and val > 0:
            return int(val)
    default = _DEFAULT_FAST_BYTES.get(platform, 8 * 2**20)
    limit = stats.get("bytes_limit")
    if isinstance(limit, (int, float)) and 0 < limit < default:
        return int(limit)
    return None


# ---------------------------------------------------------------------------
# tile arithmetic
# ---------------------------------------------------------------------------

def _pow2_floor(x: int) -> int:
    return 1 << max(int(x).bit_length() - 1, 0)


def _divisors_desc(n: int) -> list[int]:
    small, large = [], []
    i = 1
    while i * i <= n:
        if n % i == 0:
            small.append(i)
            if i != n // i:
                large.append(n // i)
        i += 1
    return large + small[::-1]  # large is built descending (n//i for i asc)


def divisor_tile(dim: int, cap: int, multiple: int = 1) -> int:
    """Largest divisor of ``dim`` that is <= cap, preferring multiples of the
    hardware tiling ``multiple``; falls back to any divisor (odd shapes)."""
    if dim <= 0:
        return 1
    cap = max(1, min(cap, dim))
    divs = _divisors_desc(dim)
    for d in divs:
        if d <= cap and d % multiple == 0:
            return d
    for d in divs:
        if d <= cap:
            return d
    return 1


def _budget(dp: DeviceParams) -> int:
    # One third of fast memory: leave headroom for double buffering and the
    # out tile, mirroring the paper's constant-factor slack in Lemma 4.4.
    return max(dp.fast_bytes // 3, 1024)


# ---------------------------------------------------------------------------
# per-op plans
# ---------------------------------------------------------------------------

def plan_scan(shape, dtype, dp: Optional[DeviceParams] = None) -> dict:
    """BP leaf size for the two-pass prefix scan: the largest lane-aligned
    block whose 4 resident buffers (in, out, local, offset) fit the envelope."""
    dp = dp or device_params()
    n = shape[-1]
    itemsize = jnp.dtype(dtype).itemsize
    cap = _pow2_floor(max(_budget(dp) // (4 * itemsize), 1))
    return {"block": divisor_tile(n, cap, dp.lane)}


# dtypes the Strassen schedule may serve: the 18 extra adds per level are
# benign under f32 accumulation (fp32 natively, bf16 with f32 acc); low-
# precision integer/fp8 matmuls lose more to the adds than the 7/8 work
# saving buys, so they stay classical
_STRASSEN_DTYPES = ("float32", "bfloat16")


def strassen_cutoff(dtype, dp: Optional[DeviceParams] = None) -> int:
    """Recursion cutoff for the Strassen-schedule matmul: the largest
    power-of-two edge where the classical envelope still wins at the queried
    device params (``costmodel.strassen_crossover_edge`` over the planner's
    budgeted fast memory, in elements of ``dtype``)."""
    dp = dp or device_params()
    itemsize = jnp.dtype(dtype).itemsize
    m_elems = max(_budget(dp) // itemsize, 2)
    b_elems = max(dp.line_bytes // itemsize, 1)
    return costmodel.strassen_crossover_edge(m_elems, b_elems)


def plan_matmul_backend(m: int, k: int, n: int, dtype,
                        dp: Optional[DeviceParams] = None) -> dict:
    """Matmul backend choice by the costmodel envelopes: ``strassen`` (plus
    its recursion ``cutoff``) when the shape is square with pow2-friendly
    halving down to the modeled crossover edge and the dtype tolerates the
    extra adds (fp32 / bf16-with-f32-acc); ``classical`` otherwise."""
    dp = dp or device_params()
    if not (m == k == n and jnp.dtype(dtype).name in _STRASSEN_DTYPES):
        return {"backend": "classical"}
    cut = strassen_cutoff(dtype, dp)
    levels, edge = 0, n
    while edge > cut and edge % 2 == 0:
        edge //= 2
        levels += 1
    # the recursion must reach the classical-wins regime by halving alone
    # (an odd edge stuck above the cutoff leaves oversized classical leaves)
    if levels == 0 or edge > cut:
        return {"backend": "classical"}
    return {"backend": "strassen", "cutoff": cut}


def plan_matmul(m: int, k: int, n: int, dtype,
                dp: Optional[DeviceParams] = None) -> dict:
    """Square (bm, bn, bk) tiles from the O(sqrt M) envelope: two operand
    tiles in ``dtype`` plus the f32 accumulator must fit the budget.  The
    plan also carries the envelope-selected ``backend`` ("classical" |
    "strassen" + recursion ``cutoff``); the registry's matmul entry point
    resolves the variant at dispatch."""
    dp = dp or device_params()
    itemsize = jnp.dtype(dtype).itemsize
    # bytes(t) = 2 t^2 itemsize (A, B panels) + 4 t^2 (f32 acc)
    edge = costmodel.oblivious_tile_edge(_budget(dp), 1, 2 * itemsize + 4)
    t = _pow2_floor(edge)
    sub = dp.sublane(dtype)
    plan = {
        "bm": divisor_tile(m, t, sub),
        "bn": divisor_tile(n, t, dp.lane),
        "bk": divisor_tile(k, t, dp.lane),
    }
    plan.update(plan_matmul_backend(m, k, n, dtype, dp))
    return plan


def plan_transpose(m: int, n: int, dtype,
                   dp: Optional[DeviceParams] = None) -> dict:
    """One square tile edge serving both dims (the kernel asserts the tile
    divides each): derived from the 2-buffer (in tile, out tile) envelope."""
    dp = dp or device_params()
    itemsize = jnp.dtype(dtype).itemsize
    t = _pow2_floor(costmodel.oblivious_tile_edge(_budget(dp), 2, itemsize))
    g = math.gcd(m, n) if m != n else m
    return {"bt": divisor_tile(g, t, dp.lane)}


# a query block this short (decode / speculative lookahead) flips the plan
# into the decode regime: the whole q fits one block and the budget goes to
# the KV stream
DECODE_MAX_SQ = 16


def plan_attention(sq: int, sk: int, hd: int, dtype,
                   dp: Optional[DeviceParams] = None, *,
                   kv_dtype=None) -> dict:
    """Flash-attention (q_block, kv_block): solve the working-set quadratic
    4 t^2 (the f32 P tile) + t * hd * (itemsize + 2 kv_itemsize + 4)
    <= budget for the square block t, then clamp each block to a divisor of
    its axis.

    Per-dtype envelopes: ``kv_dtype`` (default: the q dtype) sets the k/v
    element width independently — a quantized int8 KV cache streams panels
    at a quarter of the f32 bytes, so the same budget admits a 4x deeper KV
    panel (the SPMS register/block-reuse argument at reduced element width),
    and the sublane multiple for the KV axis follows the KV dtype's packing
    (32 int8 rows vs 8 f32).

    Decode regime (sq <= DECODE_MAX_SQ over a longer KV axis — serving a
    growing cache): the q block is the whole (tiny) query and the envelope
    is spent on the deepest lane-aligned KV panel that fits — per KV row
    the resident bytes are the k/v rows plus the f32 P column."""
    dp = dp or device_params()
    itemsize = jnp.dtype(dtype).itemsize
    kv_item = jnp.dtype(kv_dtype).itemsize if kv_dtype is not None else itemsize
    kv_sub = dp.sublane(kv_dtype if kv_dtype is not None else dtype)
    budget = _budget(dp)
    if sq <= DECODE_MAX_SQ and sk > sq:
        per_row = 2 * hd * kv_item + 4 * sq + 4  # k/v rows + P col + l bits
        kb = _pow2_floor(max(budget // per_row, 1))
        return {"q_block": sq,
                "kv_block": divisor_tile(sk, kb, kv_sub)}
    # q row + f32 acc row + k/v rows (kv width) + (m, l)
    c1 = hd * (itemsize + 2 * kv_item + 4) + 8
    t = int((-c1 + math.sqrt(c1 * c1 + 16.0 * budget)) / 8.0)
    t = _pow2_floor(max(t, 1))
    sub = dp.sublane(dtype)
    qb = divisor_tile(sq, t, sub)
    kb = divisor_tile(sk, 2 * t, kv_sub)  # kv stream gets the deeper panel
    return {"q_block": qb, "kv_block": kb}


def plan_fft(n: int, dp: Optional[DeviceParams] = None) -> dict:
    """Four-step split n = n1 * n2 with n1 ~ sqrt(n): both DFT factors stay
    inside the O(sqrt M) envelope, matching the paper's Q = (n/B) log_M n
    recursion depth of one for n <= M^2."""
    if n <= 1 or n & (n - 1) != 0:
        return {"n1": 1}
    return {"n1": 1 << (n.bit_length() - 1) // 2}


# ---------------------------------------------------------------------------
# modeled traffic (the envelope check)
# ---------------------------------------------------------------------------

def modeled_matmul_misses(m: int, k: int, n: int, dtype, plan: dict,
                          dp: Optional[DeviceParams] = None) -> float:
    """Cache-line traffic of the planned tiling; tests assert it lands within
    a constant factor of ``costmodel.seq_cache_complexity_mm``."""
    dp = dp or device_params()
    itemsize = jnp.dtype(dtype).itemsize
    bm, bn, bk = plan["bm"], plan["bn"], plan["bk"]
    steps = (m // bm) * (n // bn) * (k // bk)
    per_step = (bm * bk + bk * bn) * itemsize
    out = m * n * itemsize
    return (steps * per_step + out) / dp.line_bytes


# ---------------------------------------------------------------------------
# RunOptions resolution — the launch/model layers' single policy point
# ---------------------------------------------------------------------------

def default_attention_blocks(dp: Optional[DeviceParams] = None,
                             head_dim: int = 128,
                             dtype=jnp.bfloat16) -> tuple[int, int]:
    """Shape-agnostic blockwise-attention leaf sizes for the jnp (XLA) path:
    the same envelope as :func:`plan_attention`, uncommitted to a sequence
    length (the model clamps to the actual sequence at call time)."""
    plan = plan_attention(1 << 30, 1 << 30, head_dim, dtype, dp)
    return plan["q_block"], plan["kv_block"]


def resolve_run_options(opts, *, head_dim: int = 128, dtype=jnp.bfloat16):
    """Fill planner-owned ``None`` fields of a ``RunOptions``-like frozen
    dataclass (q_block, kv_block, autotune) from the queried device and the
    model's actual head_dim / activation dtype.  Idempotent."""
    updates = {}
    if opts.q_block is None or opts.kv_block is None:
        qb, kb = default_attention_blocks(head_dim=head_dim, dtype=dtype)
        if opts.q_block is None:
            updates["q_block"] = qb
        if opts.kv_block is None:
            updates["kv_block"] = kb
    if getattr(opts, "autotune", "off") is None:
        from repro.kernels import autotune  # layered above the planner

        updates["autotune"] = autotune.resolve_mode()
    if not updates:
        return opts
    return dataclasses.replace(opts, **updates)
