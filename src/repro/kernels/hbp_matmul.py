"""HBP-tiled matmul Pallas kernel.

The paper's Depth-n-MM / Strassen substrate adapted to the MXU: the
recursive quadrant decomposition becomes (bm x bn x bk) VMEM tiles, and the
output tiles are visited in **Morton (BI) order** — the bit-interleaved
layout of §3.2 applied to the grid schedule (shared machinery in
``repro.kernels.morton``), so successive grid steps reuse one of the two
input panels (O(1)-block-sharing across time instead of space).  fp32
accumulation in VMEM scratch; each output tile written once (limited
access).

Tile sizes default to ``None`` = planned from the queried device through
``repro.kernels.planner`` (no hard-coded block constants); pass explicit
values to override.  Ragged shapes snap each override down to the largest
divisor of its axis instead of asserting, and a degenerate snap (prime-ish
dims forcing a sub-sublane tile on a long axis) falls back to the jnp
oracle.  ``out_dtype`` lets the Strassen-schedule wrapper keep the f32
accumulator through its combination tree instead of rounding at every leaf.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.morton import grid_decode


def _mm_kernel(a_ref, b_ref, out_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(1) == nk - 1)
    def _emit():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "morton",
                                             "interpret", "out_dtype"))
def hbp_matmul(a: jax.Array, b: jax.Array, *, bm: Optional[int] = None,
               bn: Optional[int] = None, bk: Optional[int] = None,
               morton: bool = True, interpret: bool = True,
               out_dtype=None) -> jax.Array:
    """C = A @ B with Morton-ordered output tiles.  A: (m, k), B: (k, n)."""
    from repro.kernels import planner

    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    out_dtype = jnp.dtype(a.dtype if out_dtype is None else out_dtype)
    if bm is None or bn is None or bk is None:
        plan = planner.plan_matmul(m, k, n, a.dtype)
        bm = bm if bm is not None else plan["bm"]
        bn = bn if bn is not None else plan["bn"]
        bk = bk if bk is not None else plan["bk"]
    # ragged dims snap each tile to the largest divisor of its axis (planner
    # plans are divisor-exact already; this covers explicit/tuned overrides)
    bm = planner.divisor_tile(m, min(int(bm), m))
    bn = planner.divisor_tile(n, min(int(bn), n))
    bk = planner.divisor_tile(k, min(int(bk), k))
    # a degenerate snap (prime-ish dim -> sub-sublane tile on a long axis)
    # would run a catastrophically fine grid; take the jnp oracle instead
    if (bm < 8 <= m) or (bn < 8 <= n) or (bk < 8 <= k):
        return jnp.dot(a.astype(jnp.float32),
                       b.astype(jnp.float32)).astype(out_dtype)
    nm, nn, nk = m // bm, n // bn, k // bk

    decode = grid_decode(nm, nn, morton=morton)
    grid = (nm * nn, nk)

    def a_map(g, kk):
        i, _ = decode(g)
        return (i, kk)

    def b_map(g, kk):
        _, j = decode(g)
        return (kk, j)

    def o_map(g, kk):
        i, j = decode(g)
        return (i, j)

    return pl.pallas_call(
        functools.partial(_mm_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), a_map),
            pl.BlockSpec((bk, bn), b_map),
        ],
        out_specs=pl.BlockSpec((bm, bn), o_map),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
