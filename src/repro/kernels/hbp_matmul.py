"""HBP-tiled matmul Pallas kernel.

The paper's Depth-n-MM / Strassen substrate adapted to the MXU: the
recursive quadrant decomposition becomes (bm x bn x bk) VMEM tiles, and the
output tiles are visited in **Morton (BI) order** — the bit-interleaved
layout of §3.2 applied to the grid schedule, so successive grid steps reuse
one of the two input panels (O(1)-block-sharing across time instead of
space).  fp32 accumulation in VMEM scratch; each output tile written once
(limited access).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _compact1by1(x):
    x = x & 0x55555555
    x = (x | (x >> 1)) & 0x33333333
    x = (x | (x >> 2)) & 0x0F0F0F0F
    x = (x | (x >> 4)) & 0x00FF00FF
    x = (x | (x >> 8)) & 0x0000FFFF
    return x


def _morton_ij(g):
    """Decode Morton code -> (i, j) with traced integer ops."""
    return _compact1by1(g >> 1), _compact1by1(g)


def _mm_kernel(a_ref, b_ref, out_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(1) == nk - 1)
    def _emit():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "morton", "interpret"))
def hbp_matmul(a: jax.Array, b: jax.Array, *, bm: int = 128, bn: int = 128,
               bk: int = 128, morton: bool = True, interpret: bool = True) -> jax.Array:
    """C = A @ B with Morton-ordered output tiles.  A: (m, k), B: (k, n)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    nm, nn, nk = m // bm, n // bn, k // bk

    if morton and nm == nn and (nm & (nm - 1)) == 0:
        grid = (nm * nn, nk)

        def a_map(g, kk):
            i, _ = _morton_ij(g)
            return (i, kk)

        def b_map(g, kk):
            _, j = _morton_ij(g)
            return (kk, j)

        def o_map(g, kk):
            i, j = _morton_ij(g)
            return (i, j)
    else:
        grid = (nm * nn, nk)

        def a_map(g, kk):
            return (g // nn, kk)

        def b_map(g, kk):
            return (kk, g % nn)

        def o_map(g, kk):
            return (g // nn, g % nn)

    return pl.pallas_call(
        functools.partial(_mm_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), a_map),
            pl.BlockSpec((bk, bn), b_map),
        ],
        out_specs=pl.BlockSpec((bm, bn), o_map),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
