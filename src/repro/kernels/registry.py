"""Kernel registry: one policy-driven dispatch path for every op.

Each op registers a ``KernelSpec`` — a Pallas implementation, the pure-jnp
``ref.py`` oracle, a planner hook that derives tile kwargs from the queried
device (``repro.kernels.planner``), a backend predicate saying when the
Pallas path compiles natively, and capability metadata (``has_vjp``, the
``needs`` shape/dtype gate).  Two entry points consume it:

``resolve(name, policy=None, **context)``
    The single backend-resolution code path (it replaced the per-op
    resolvers: ``resolve_matmul_impl``, the attention impl branch, and
    ``default_impl``).  Looks the op up in the ambient
    :class:`~repro.kernels.policy.ExecutionPolicy` (``"*"`` wildcard,
    default ``"auto"``), expands ``auto`` via ``supported()``, and
    downgrades a Pallas choice to ``jnp`` when capability metadata says the
    kernel cannot serve the call — no registered backward under a possibly
    differentiated caller, or a failing ``needs(**context)`` predicate.

``dispatch(name, *args, impl=None, interpret=None, **kwargs)``
    Invokes the resolved backend: the oracle for ``jnp``/``ref``, else the
    Pallas kernel with planner-derived tiles, overlaid by any persisted
    autotune measurement (``repro.kernels.autotune``), under the policy's
    per-op variant overrides, under explicit call-site tile kwargs.  The
    ``impl`` kwarg is the per-call escape hatch (benchmark arms, oracle
    comparisons); everything else reads the policy.  Dispatch applies the
    ``needs`` capability gate to policy-sourced resolutions, but it cannot
    know whether the caller will differentiate — callers that might (the
    model layer) must pre-resolve through :func:`resolve`, whose
    ``has_vjp`` gate covers autodiff.

Registered ops: ``scan``, ``matmul``, ``transpose``, ``attention``, ``fft``
— the paper's trio of scans / matrix computations / FFT plus the BP
online-softmax reduce.  The same names also key the *simulator* side:
``simulator_program(name, n)`` builds the op's access-trace HBP program
from ``repro.core.algorithms``, so kernel dispatch and simulator cost
cross-checks share one op namespace.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Callable, Optional, Tuple

import jax

from repro.kernels import planner, policy, ref
from repro.kernels.bi_fft import bi_fft
from repro.kernels.bi_transpose import bi_transpose
from repro.kernels.bp_scan import bp_scan
from repro.kernels.flash_attention import flash_attention
from repro.kernels.strassen_matmul import matmul as backend_matmul


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@dataclass(frozen=True)
class KernelSpec:
    """One registered op.

    ``plan(*arrays) -> dict`` produces the tile kwargs for the Pallas path;
    ``pallas_only`` names the kwargs (tiles + schedule flags) that must be
    stripped before calling the oracle, which takes semantic kwargs only.
    ``supported() -> bool`` says whether the Pallas path compiles natively
    on the current backend (it always *runs* via interpret mode).
    ``has_vjp`` marks ops whose Pallas implementation registers a custom
    backward (safe under autodiff) — :func:`resolve` downgrades the others
    to the jnp path for model callers, which cannot tell a forward-only
    call from a traced-for-grad one.  ``needs(**context) -> bool`` is the
    shape/dtype capability gate: call-site context the kernel cannot serve
    (e.g. attention with a custom softmax scale or a traced window) also
    resolves to jnp.  ``simulator(n, mem, **kw)`` builds the op's
    access-trace twin from ``repro.core.algorithms`` (None = no simulator
    program for this op)."""

    name: str
    pallas: Callable
    ref: Callable
    plan: Callable
    pallas_only: Tuple[str, ...] = ()
    supported: Callable[[], bool] = on_tpu
    has_vjp: bool = False
    needs: Optional[Callable[..., bool]] = None
    simulator: Optional[Callable] = None


_REGISTRY: dict[str, KernelSpec] = {}


def register(spec: KernelSpec) -> KernelSpec:
    if spec.name in _REGISTRY:
        raise ValueError(f"kernel {spec.name!r} already registered")
    _REGISTRY[spec.name] = spec
    return spec


def get(name: str) -> KernelSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown kernel {name!r}; registered: {names()}") from None


def names() -> list[str]:
    return sorted(_REGISTRY)


def resolve(name: str, pol: Optional[policy.ExecutionPolicy] = None,
            *, differentiable: bool = True, **context) -> str:
    """Resolve the op's backend under the (ambient) policy: ``"pallas"`` or
    ``"jnp"``.  ``auto`` asks ``supported()``; a forced/auto ``pallas``
    downgrades to ``jnp`` when the kernel lacks a registered backward
    (``differentiable`` callers — the model-layer default) or its ``needs``
    predicate rejects the call context.  ``ref`` resolves like ``jnp``:
    both mean "not the Pallas kernel" at this layer."""
    spec = get(name)
    if pol is None:
        pol = policy.current()
    choice = pol.impl_for(name)
    if choice == "auto":
        choice = "pallas" if spec.supported() else "jnp"
    elif choice == "ref":
        choice = "jnp"
    if choice == "pallas":
        if differentiable and not spec.has_vjp:
            choice = "jnp"
        elif spec.needs is not None and not spec.needs(**context):
            choice = "jnp"
    return choice


# ops already warned about dropped overrides (warn once per op, not per
# trace); reset_warnings() clears it between tests
_WARNED_DROPPED: set[str] = set()


def reset_warnings() -> None:
    """Test hook: clear the registry's and autotune's warn/log-once state so
    one test's first-warning does not swallow the next test's."""
    _WARNED_DROPPED.clear()
    from repro.kernels import autotune

    autotune._INTERP_LOGGED.clear()


def _check_dropped_overrides(name: str, overrides: dict, *, strict: bool) -> None:
    """The oracle takes semantic kwargs only, so explicit tile overrides on
    the ref path never reach a kernel.  Silence here means an experiment can
    read 'fixed-tile' numbers that actually ran the un-tiled oracle — warn
    once per op, or raise outright under ``REPRO_STRICT_TILES`` / a
    ``strict_tiles`` policy."""
    dropped = sorted(k for k, v in overrides.items() if v is not None)
    if not dropped:
        return
    msg = (f"dispatch({name!r}): tile override(s) {dropped} ignored on the "
           "ref path (the oracle takes semantic kwargs only); force "
           "impl='pallas' to exercise the tiles")
    if strict:
        raise ValueError(msg)
    if name not in _WARNED_DROPPED:
        _WARNED_DROPPED.add(name)
        warnings.warn(msg, stacklevel=3)


def dispatch(name: str, *args, impl: Optional[str] = None,
             interpret: Optional[bool] = None, **kwargs):
    """Generic dispatch under the ambient execution policy.  ``impl`` is
    the per-call override (``"auto"`` | ``"jnp"``/``"ref"`` | ``"pallas"``);
    None reads the policy's per-op map.  The oracle serves ``jnp``/``ref``;
    ``pallas`` runs the kernel with planner tiles overlaid by autotune
    measurements, the policy's per-op variant overrides, and explicit tile
    kwargs (strongest last)."""
    spec = get(name)
    pol = policy.current()
    native = spec.supported()
    forced = impl is not None and impl != "auto"
    if impl is None:
        impl = pol.impl_for(name)
    if impl == "auto":
        impl = "pallas" if native else "ref"
    # an unforced pallas (policy-sourced, or an explicit impl="auto") still
    # honors the op's capability gate: call context the kernel cannot take
    # (the ``needs`` predicate over the semantic kwargs) falls back to the
    # oracle rather than erroring inside the kernel.  An explicit
    # impl="pallas" skips this — the per-call escape hatch means "I know
    # what the kernel takes"
    if (not forced and impl == "pallas" and spec.needs is not None
            and not spec.needs(**kwargs)):
        impl = "ref"
    explicit = {k: kwargs.pop(k) for k in list(kwargs) if k in spec.pallas_only}
    explicit = {k: v for k, v in explicit.items() if v is not None}
    pol_variants = {k: v for k, v in pol.variant_for(name).items()
                    if k in spec.pallas_only}
    if impl in ("ref", "jnp"):
        # policy-scoped variants are overrides too: dropping them silently
        # would let a 'forced-variant' experiment read oracle numbers
        _check_dropped_overrides(name, {**pol_variants, **explicit},
                                 strict=pol.strict_tiles)
        return spec.ref(*args, **kwargs)
    overrides = dict(pol_variants)
    overrides.update(explicit)
    tiles = dict(spec.plan(*args))
    from repro.kernels import autotune  # the measured layer above dispatch

    # forced variant knobs (e.g. matmul backend) select which table entry to
    # replay — key the lookup on them alongside the semantic kwargs; tile
    # overrides stay out (they win over the overlay below regardless)
    variant = {k: v for k, v in overrides.items()
               if k in autotune.variant_keys(name)}
    tiles.update(autotune.overlay(name, args,
                                  search_kwargs={**kwargs, **variant}))
    tiles.update(overrides)
    if interpret is None:
        # per-op variant knob (--impl 'op=pallas:interpret=true') sits
        # between the explicit call arg and the policy-global flag
        interpret = pol.variant_for(name).get("interpret")
    if interpret is None:
        interpret = pol.interpret if pol.interpret is not None else not native
    return spec.pallas(*args, interpret=interpret, **kwargs, **tiles)


# ---------------------------------------------------------------------------
# simulator namespace (ROADMAP: one op namespace for kernels + simulator)
# ---------------------------------------------------------------------------

def simulator_program(name: str, n: int, mem=None, **kwargs):
    """Build the op's access-trace HBP program (``repro.core.algorithms``)
    under the same name the kernel dispatches as, so simulator cost
    cross-checks and ``KernelSpec`` lookups share one namespace.  ``n`` is
    the op's natural size (matrix edge for matmul/transpose, length for
    scan/fft); allocates a fresh ``core.hbp.Memory`` unless given one.
    Returns whatever the core builder returns (a program, or a program list
    for multi-pass ops like the two-pass prefix scan)."""
    spec = get(name)
    if spec.simulator is None:
        raise KeyError(f"kernel {name!r} has no registered simulator program; "
                       f"ops with one: "
                       f"{[s for s in names() if get(s).simulator is not None]}")
    if mem is None:
        from repro.core.hbp import Memory

        mem = Memory()
    return spec.simulator(n, mem, **kwargs)


def _sim_scan(n, mem, **kw):
    from repro.core import algorithms

    return algorithms.prefix_sums_programs(n, mem, **kw)


def _sim_matmul(n, mem, **kw):
    from repro.core import algorithms

    return algorithms.strassen_program(n, mem, **kw)


def _sim_transpose(n, mem, **kw):
    from repro.core import algorithms

    return algorithms.MTBI(n, mem, **kw)


def _sim_fft(n, mem, **kw):
    from repro.core import algorithms

    return algorithms.fft_program(n, mem, **kw)


# ---------------------------------------------------------------------------
# registrations
# ---------------------------------------------------------------------------

register(KernelSpec(
    name="scan",
    pallas=bp_scan,
    ref=ref.bp_scan_ref,
    plan=lambda x: planner.plan_scan(x.shape, x.dtype),
    pallas_only=("block",),
    simulator=_sim_scan,
))

register(KernelSpec(
    name="matmul",
    # the variant entry point: resolves the plan's backend field
    # ("classical" -> hbp_matmul, "strassen" -> the quadrant recursion) and
    # carries a custom VJP (dA = g B^T, dB = A^T g through the same kernels)
    pallas=backend_matmul,
    ref=ref.matmul_ref,
    plan=lambda a, b: planner.plan_matmul(a.shape[0], a.shape[1], b.shape[1],
                                          a.dtype),
    pallas_only=("bm", "bn", "bk", "morton", "backend", "cutoff"),
    has_vjp=True,
    simulator=_sim_matmul,
))

register(KernelSpec(
    name="transpose",
    pallas=bi_transpose,
    ref=ref.transpose_ref,
    plan=lambda x: planner.plan_transpose(x.shape[0], x.shape[1], x.dtype),
    pallas_only=("bt", "morton"),
    simulator=_sim_transpose,
))

register(KernelSpec(
    name="attention",
    pallas=flash_attention,
    ref=ref.flash_attention_ref,
    # per-dtype envelopes: an int8 KV cache budgets a deeper panel
    plan=lambda q, k, v: planner.plan_attention(q.shape[1], k.shape[1],
                                                q.shape[2], q.dtype,
                                                kv_dtype=k.dtype),
    pallas_only=("q_block", "kv_block"),
    # recomputation-style backward kernels (dq + dk/dv) registered as a
    # custom VJP in flash_attention — training no longer routes around it
    has_vjp=True,
    # the kernel hard-codes the 1/sqrt(hd) scale, and its causal/window
    # kwargs are static — a custom softmax scale or a traced (scan-carried)
    # per-layer window cannot take the kernel route
    needs=lambda softmax_scale=None, window=None, **_: (
        softmax_scale is None and isinstance(window, (int, type(None)))),
))

register(KernelSpec(
    name="fft",
    pallas=bi_fft,
    ref=ref.fft_ref,
    plan=lambda x: planner.plan_fft(x.shape[-1]),
    pallas_only=("n1",),
    simulator=_sim_fft,
))
