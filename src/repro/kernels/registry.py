"""Kernel registry: one cost-model-driven dispatch path for every op.

Each op registers a ``KernelSpec`` — a Pallas implementation, the pure-jnp
``ref.py`` oracle, a planner hook that derives tile kwargs from the queried
device (``repro.kernels.planner``), and a backend predicate saying when the
Pallas path compiles natively.  ``dispatch(name, *args, **kwargs)`` replaces
the four near-identical per-op wrappers the substrate used to carry in
``ops.py``: it routes to the oracle on unsupported backends (so model code
lowered on CPU sees the XLA-fused path, not the interpreter's loop nest),
and otherwise calls the Pallas kernel with planner tiles merged under any
explicit overrides.

Registered ops: ``scan``, ``matmul``, ``transpose``, ``attention``, ``fft``
— the paper's trio of scans / matrix computations / FFT plus the BP
online-softmax reduce.
"""
from __future__ import annotations

import os
import warnings
from dataclasses import dataclass
from typing import Callable, Optional, Tuple

import jax

from repro.kernels import planner, ref
from repro.kernels.bi_fft import bi_fft
from repro.kernels.bi_transpose import bi_transpose
from repro.kernels.bp_scan import bp_scan
from repro.kernels.flash_attention import flash_attention
from repro.kernels.strassen_matmul import matmul as backend_matmul


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@dataclass(frozen=True)
class KernelSpec:
    """One registered op.

    ``plan(*arrays) -> dict`` produces the tile kwargs for the Pallas path;
    ``pallas_only`` names the kwargs (tiles + schedule flags) that must be
    stripped before calling the oracle, which takes semantic kwargs only.
    ``supported() -> bool`` says whether the Pallas path compiles natively
    on the current backend (it always *runs* via interpret mode).
    ``has_vjp`` marks ops whose Pallas implementation registers a custom
    backward (safe under autodiff) — callers that keep a jnp fallback for
    training (``models.common.attention``) consult it instead of assuming
    the kernel is inference-only."""

    name: str
    pallas: Callable
    ref: Callable
    plan: Callable
    pallas_only: Tuple[str, ...] = ()
    supported: Callable[[], bool] = on_tpu
    has_vjp: bool = False


_REGISTRY: dict[str, KernelSpec] = {}


def register(spec: KernelSpec) -> KernelSpec:
    if spec.name in _REGISTRY:
        raise ValueError(f"kernel {spec.name!r} already registered")
    _REGISTRY[spec.name] = spec
    return spec


def get(name: str) -> KernelSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown kernel {name!r}; registered: {names()}") from None


def names() -> list[str]:
    return sorted(_REGISTRY)


def default_impl(name: str) -> str:
    """The backend the generic dispatch will pick: 'pallas' or 'ref'."""
    return "pallas" if get(name).supported() else "ref"


# ops already warned about dropped overrides (warn once per op, not per trace)
_WARNED_DROPPED: set[str] = set()


def _check_dropped_overrides(name: str, overrides: dict) -> None:
    """The oracle takes semantic kwargs only, so explicit tile overrides on
    the ref path never reach a kernel.  Silence here means an experiment can
    read 'fixed-tile' numbers that actually ran the un-tiled oracle — warn
    once per op, or raise outright under ``REPRO_STRICT_TILES``."""
    dropped = sorted(k for k, v in overrides.items() if v is not None)
    if not dropped:
        return
    msg = (f"dispatch({name!r}): tile override(s) {dropped} ignored on the "
           "ref path (the oracle takes semantic kwargs only); pass "
           "prefer_ref=False to exercise the tiles")
    if os.environ.get("REPRO_STRICT_TILES"):
        raise ValueError(msg)
    if name not in _WARNED_DROPPED:
        _WARNED_DROPPED.add(name)
        warnings.warn(msg, stacklevel=3)


def dispatch(name: str, *args, prefer_ref: Optional[bool] = None,
             interpret: Optional[bool] = None, **kwargs):
    """Generic dispatch: oracle when ``prefer_ref`` (default: whenever the
    Pallas path would not compile natively), else the Pallas kernel with
    planner-derived tiles, overlaid by any persisted autotune measurement
    (``repro.kernels.autotune``), under any explicit tile overrides."""
    spec = get(name)
    native = spec.supported()
    if prefer_ref is None:
        prefer_ref = not native
    overrides = {k: kwargs.pop(k) for k in list(kwargs) if k in spec.pallas_only}
    if prefer_ref:
        _check_dropped_overrides(name, overrides)
        return spec.ref(*args, **kwargs)
    tiles = dict(spec.plan(*args))
    from repro.kernels import autotune  # the measured layer above dispatch

    # forced variant knobs (e.g. matmul backend) select which table entry to
    # replay — key the lookup on them alongside the semantic kwargs; tile
    # overrides stay out (they win over the overlay below regardless)
    variant = {k: v for k, v in overrides.items()
               if v is not None and k in autotune.variant_keys(name)}
    tiles.update(autotune.overlay(name, args,
                                  search_kwargs={**kwargs, **variant}))
    tiles.update({k: v for k, v in overrides.items() if v is not None})
    if interpret is None:
        interpret = not native
    return spec.pallas(*args, interpret=interpret, **kwargs, **tiles)


# ---------------------------------------------------------------------------
# registrations
# ---------------------------------------------------------------------------

register(KernelSpec(
    name="scan",
    pallas=bp_scan,
    ref=ref.bp_scan_ref,
    plan=lambda x: planner.plan_scan(x.shape, x.dtype),
    pallas_only=("block",),
))

register(KernelSpec(
    name="matmul",
    # the variant entry point: resolves the plan's backend field
    # ("classical" -> hbp_matmul, "strassen" -> the quadrant recursion) and
    # carries a custom VJP (dA = g B^T, dB = A^T g through the same kernels)
    pallas=backend_matmul,
    ref=ref.matmul_ref,
    plan=lambda a, b: planner.plan_matmul(a.shape[0], a.shape[1], b.shape[1],
                                          a.dtype),
    pallas_only=("bm", "bn", "bk", "morton", "backend", "cutoff"),
    has_vjp=True,
))

register(KernelSpec(
    name="transpose",
    pallas=bi_transpose,
    ref=ref.transpose_ref,
    plan=lambda x: planner.plan_transpose(x.shape[0], x.shape[1], x.dtype),
    pallas_only=("bt", "morton"),
))

register(KernelSpec(
    name="attention",
    pallas=flash_attention,
    ref=ref.flash_attention_ref,
    plan=lambda q, k, v: planner.plan_attention(q.shape[1], k.shape[1],
                                                q.shape[2], q.dtype),
    pallas_only=("q_block", "kv_block"),
    # recomputation-style backward kernels (dq + dk/dv) registered as a
    # custom VJP in flash_attention — training no longer routes around it
    has_vjp=True,
))

register(KernelSpec(
    name="fft",
    pallas=bi_fft,
    ref=ref.fft_ref,
    plan=lambda x: planner.plan_fft(x.shape[-1]),
    pallas_only=("n1",),
))
