"""jit'd public wrappers for the Pallas kernels.

On the TPU target the kernels compile natively (interpret=False); on this
CPU container they run in interpret mode (the kernel body executes through
JAX ops) — tests validate them against the ``ref.py`` oracles either way.
``prefer_ref=True`` dispatches to the pure-jnp reference (used by the model
code on CPU so dry-run HLO reflects the XLA-fused path rather than the
interpreter's loop nest).
"""
from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.bp_scan import bp_scan as _bp_scan
from repro.kernels.bi_transpose import bi_transpose as _bi_transpose
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.hbp_matmul import hbp_matmul as _hbp_matmul


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def scan(x, *, block: int = 512, prefer_ref: bool | None = None):
    if prefer_ref or (prefer_ref is None and not on_tpu()):
        return ref.bp_scan_ref(x)
    return _bp_scan(x, block=block, interpret=not on_tpu())


def matmul(a, b, *, bm: int = 128, bn: int = 128, bk: int = 128,
           prefer_ref: bool | None = None):
    if prefer_ref or (prefer_ref is None and not on_tpu()):
        return ref.matmul_ref(a, b)
    return _hbp_matmul(a, b, bm=bm, bn=bn, bk=bk, interpret=not on_tpu())


def transpose(x, *, bt: int = 128, prefer_ref: bool | None = None):
    if prefer_ref or (prefer_ref is None and not on_tpu()):
        return ref.transpose_ref(x)
    return _bi_transpose(x, bt=bt, interpret=not on_tpu())


def attention(q, k, v, *, causal: bool = True, window: int = 0,
              q_block: int = 256, kv_block: int = 256,
              prefer_ref: bool | None = None):
    if prefer_ref or (prefer_ref is None and not on_tpu()):
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return _flash(q, k, v, causal=causal, window=window, q_block=q_block,
                  kv_block=kv_block, interpret=not on_tpu())
