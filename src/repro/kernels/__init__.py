"""Resource-oblivious kernel substrate.

The paper's claim — sequential-level cache and block costs *without knowing
M or B* — carried from the simulator into the Pallas layer.  Four policy
points, each in exactly one module:

``policy``
    The ambient :class:`~repro.kernels.policy.ExecutionPolicy`: ONE place
    where every backend/variant/autotune decision lives, the way the
    paper's scheduler keeps *where a task runs* out of the computation dag.
    A frozen value object (per-op ``impl`` map with a ``"*"`` wildcard,
    per-op ``variants``, ``autotune`` mode, ``interpret``,
    ``strict_tiles``) on a context stack: the base is assembled from the
    environment (``REPRO_IMPL`` with the
    ``op=backend[:knob=value]*[,op=backend...]`` grammar — ``:knob=value``
    suffixes set per-op variant knobs, e.g.
    ``attention=pallas:kv_dtype=int8`` for the quantized KV cache or
    ``matmul=pallas:qkv_fused=true`` for fused QKV projections —
    ``REPRO_STRICT_TILES``, ``REPRO_INTERPRET``), launchers
    ``install()`` the ``--impl`` flag as a process layer, and
    ``apply()``/``pin()`` push scoped overrides (a pin records its
    reason).  An ``interpret`` variant knob
    (``--impl 'op=pallas:interpret=true'``) forces interpret mode per op,
    sitting between the explicit call arg and the policy-global flag.
    Model code never names a backend; the deprecated
    ``RunOptions.attention_impl``/``matmul_impl`` knobs survive only as a
    compat shim that constructs an equivalent scope.

``registry``
    ``resolve(name, **context)`` is the single backend-resolution code path
    (it replaced ``resolve_matmul_impl``, the attention impl branch, and
    ``default_impl``): policy lookup, ``auto`` expansion via
    ``supported()``, then the capability gates — ``has_vjp`` (ops without a
    registered backward never serve possibly-differentiated model callers)
    and the per-op ``needs`` predicate (shape/dtype context the kernel
    cannot take, e.g. a custom softmax scale).  ``dispatch(name, *args,
    **kw)`` is the only way model / launch / benchmark code invokes a
    kernel: the oracle for a jnp resolution, else the Pallas kernel with
    planner tiles + autotune overlay + the policy's variant overrides +
    explicit call-site kwargs (strongest last); ``impl=`` on the call is
    the per-call escape hatch for experiments.  Each op (``scan``,
    ``matmul``, ``transpose``, ``attention``, ``fft``) registers a
    ``KernelSpec``; the ``attention`` kernel covers cached decode via
    ``q_offset``/``kv_len`` and registers a recomputation backward, so
    serving prefill/decode and training all dispatch through one path.
    Both decode operands also take per-row ``(rows,)`` vectors — ``rows``
    dividing the folded batch*heads axis, each row's scalar fanning out
    over its ``bh // rows`` folded heads (the batch-major fold) — read
    per-lane from SMEM, so one launch serves a continuous batch whose
    slots sit at different cache depths: concrete vectors keep the
    static grid shrink (to the max length), traced vectors keep the
    no-recompile property across ragged batch compositions
    (``launch.engine`` is the consumer).  A ``kv_len == 0`` lane attends
    nothing and emits exact zeros (the parked-row contract).  The caller
    side of that contract lives in ``repro.models.cache``: the
    ``DecodeCache`` layouts — ``LinearKV`` (dense slabs + int8 scales,
    per-row ``pos``), ``RingKV`` (a wrapped window buffer whose
    ``attend_lens``/``slot_positions`` map raw slots onto the kernel's
    per-row ``q_offset``/``kv_len`` vectors, sound because causal softmax
    is permutation-invariant over the live window), ``CrossKV`` (frozen
    after the first chunk) and ``StateCarry`` (recurrent conv/LRU/SSD
    state with a per-row validity mask) — are the single source of truth
    for per-row cache state across every model family.
    GQA is kernel-native: callers hand K/V over at their *native* head
    count with ``n_heads`` declaring the query head count, and the kv
    ``index_map`` routes every query head's grid steps into its group's KV
    row (dk/dv group-sum in the transposed grid's scratch) — no caller ever
    materializes a cache-sized ``repeat_kv``.  An int8 KV cache
    (``k_scale``/``v_scale`` per (batch, kv-head), selected by the policy's
    attention ``kv_dtype=int8`` variant) dequantizes inside the kernel's
    block load, streaming the cache at a quarter of the f32 bytes.
    ``simulator_program(name, n)`` builds the op's access-trace HBP program
    (``core.algorithms``) under the same name, so kernel dispatch and
    simulator cost cross-checks share one op namespace.

``planner``
    Derives every tile shape at trace time from *queried* device parameters
    (fast-memory bytes, lane/sublane tiling, dtype width) pushed through the
    ``repro.core.costmodel`` envelopes (``oblivious_tile_edge``,
    ``seq_cache_complexity_*``).  No kernel signature carries a hard-coded
    block size; ``plan_*`` functions return divisor-exact tile dicts
    (``plan_attention`` budgets per KV dtype — an int8 cache stream earns a
    proportionally deeper KV panel) and
    ``resolve_run_options`` fills the model layer's ``RunOptions`` tiles.
    ``REPRO_FAST_BYTES`` overrides the queried fast-memory size.

``morton``
    The §3.2 bit-interleaved (BI) codec on plain integer arithmetic (works
    on traced grid indices), and ``grid_decode(nm, nn)`` — the shared grid
    scheduler giving Morton order on square power-of-two tile grids with a
    row-major fallback.  Used by ``hbp_matmul``, ``bi_transpose``, and
    ``flash_attention``; cross-validated against ``repro.core.layouts``.

Backend selection
-----------------
``matmul`` is a multi-backend op: ``planner.plan_matmul`` carries a
``backend`` field chosen by comparing the costmodel envelopes
(``seq_cache_complexity_strassen`` vs the classical Q) at the queried
device params — "strassen" (the paper's Type-2 Depth-n-MM exemplar,
W = n^2.807) for square, pow2-friendly, fp32/bf16 shapes above the modeled
crossover edge (~sqrt M), "classical" otherwise — plus the recursion
``cutoff`` beneath which ``strassen_matmul``'s 7-product quadrant schedule
leaves dispatch to the Morton-ordered ``hbp_matmul`` tile kernel with f32
accumulation preserved through the combination tree.  The registry's
``matmul`` entry (``strassen_matmul.matmul``) resolves the variant at
dispatch and registers a custom VJP (dA = g Bᵀ, dB = Aᵀ g, each
re-planned for its own shape), so model matmuls (``models.common``'s
``project``/``gated_mlp``/``logits_matmul``/``expert_project`` — MLPs, QKV
and output projections, logits, MoE expert slabs) route through the
kernels under training and serving alike whenever the ambient policy says
so.  A forced variant (policy ``variants`` or call-site kwarg) keys the
autotune replay lookup, so a pinned-classical run never replays tiles
tuned for the Strassen entry.

Tuning
------
``autotune`` closes the measure→persist→replay loop over the planner: the
analytic plans stay the source of truth, but measured winners (searched on a
power-of-two ladder around the analytic point, filtered by the costmodel
envelope and each kernel's divisibility constraints) are persisted per
``(device_kind, op, shape_class, dtype, semantic flags)`` as JSON under
``REPRO_TUNE_DIR`` (default ``~/.cache/repro/autotune``) and overlaid at
dispatch time.  The mode resolves through ``autotune.mode()``: an
``autotune`` field set on the ambient policy (a scope or the RunOptions
shim) wins, then the launcher's ``startup``/``set_mode`` pin, then
``REPRO_AUTOTUNE``, else ``off``:

  * ``off``    — analytic plans only; the default for bare dispatch so
    benchmarks and tests see the pure planner unless they opt in;
  * ``replay`` — overlay persisted measurements; a cold cache is a no-op;
    the launchers' startup default;
  * ``search`` — replay, plus a table miss on concrete (non-traced) arrays
    triggers an in-line timed search whose winner is persisted.

``benchmarks/autotune.py`` populates tables across a shape sweep;
``benchmarks/bench_kernels.py`` reports the resulting ``pallas_tuned_us``
next to the fixed/planned arms.  Kernel signatures stay oblivious: tuning
never adds a knob to a kernel, it only picks values for the existing ones.

Kernel modules (``bp_scan``, ``hbp_matmul``, ``strassen_matmul``,
``bi_transpose``, ``flash_attention``, ``bi_fft``) stay importable directly
for tests and experiments; ``ref`` holds the pure-jnp oracles.

Layers above: ``repro.models`` calls kernels only through ``dispatch``;
``repro.launch`` stacks the serving tiers on top of the models — lockstep
``serve.Server``, continuous-batching ``engine.Engine``, and the
multi-replica ``router.Router`` fleet, whose replicas each carry this
layer's policy ``describe()`` and autotune ``provenance()`` as their
per-replica provenance rows (replicas on different device kinds replay
different tuned tables; the router surfaces which).
"""
from repro.kernels import autotune, morton, planner, policy, ref, registry
from repro.kernels.bi_fft import bi_fft
from repro.kernels.bi_transpose import bi_transpose
from repro.kernels.bp_scan import bp_scan
from repro.kernels.flash_attention import flash_attention
from repro.kernels.hbp_matmul import hbp_matmul
from repro.kernels.policy import ExecutionPolicy
from repro.kernels.registry import dispatch, resolve
from repro.kernels.strassen_matmul import strassen_matmul

__all__ = [
    "autotune",
    "morton",
    "planner",
    "policy",
    "ref",
    "registry",
    "ExecutionPolicy",
    "dispatch",
    "resolve",
    "bp_scan",
    "bi_transpose",
    "bi_fft",
    "flash_attention",
    "hbp_matmul",
    "strassen_matmul",
]
