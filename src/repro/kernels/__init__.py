"""Resource-oblivious kernel substrate.

The paper's claim — sequential-level cache and block costs *without knowing
M or B* — carried from the simulator into the Pallas layer.  Three policy
points, each in exactly one module:

``registry``
    ``dispatch(name, *args, **kw)`` is the only way model / launch /
    benchmark code invokes a kernel.  Each op (``scan``, ``matmul``,
    ``transpose``, ``attention``, ``fft``) registers a ``KernelSpec``
    holding its Pallas implementation, its ``ref.py`` oracle, a planner
    hook, and a backend predicate.  Dispatch routes to the oracle on
    backends where Pallas would not compile natively (``prefer_ref``
    overrides), else calls the kernel with planned tiles; explicit tile
    kwargs (``bm``/``bn``/``bk``, ``block``, ``bt``, ``q_block``/
    ``kv_block``, ``n1``) win over the plan.
    ``default_impl(name)`` exposes the choice to callers that keep their
    own jnp path (e.g. blockwise attention with its custom VJP), and
    ``KernelSpec.has_vjp`` marks ops whose Pallas path is itself safe
    under autodiff.  ``attention`` is: the flash kernel registers a
    recomputation-style backward (dq over the forward's grid, dk/dv over
    the transposed KV-outer grid) and covers cached decode via two
    semantic kwargs — ``q_offset`` (absolute position of query row 0,
    traced scalars welcome) and ``kv_len`` (valid KV prefix; static
    values shrink the KV grid itself, traced ones skip dead blocks with
    ``pl.when``) — so serving prefill/decode and training all dispatch
    through the same kernel.

``planner``
    Derives every tile shape at trace time from *queried* device parameters
    (fast-memory bytes, lane/sublane tiling, dtype width) pushed through the
    ``repro.core.costmodel`` envelopes (``oblivious_tile_edge``,
    ``seq_cache_complexity_*``).  No kernel signature carries a hard-coded
    block size; ``plan_*`` functions return divisor-exact tile dicts and
    ``resolve_run_options`` fills the model layer's ``RunOptions`` tiles.
    ``REPRO_FAST_BYTES`` overrides the queried fast-memory size.

``morton``
    The §3.2 bit-interleaved (BI) codec on plain integer arithmetic (works
    on traced grid indices), and ``grid_decode(nm, nn)`` — the shared grid
    scheduler giving Morton order on square power-of-two tile grids with a
    row-major fallback.  Used by ``hbp_matmul``, ``bi_transpose``, and
    ``flash_attention``; cross-validated against ``repro.core.layouts``.

Backend selection
-----------------
``matmul`` is a multi-backend op: ``planner.plan_matmul`` carries a
``backend`` field chosen by comparing the costmodel envelopes
(``seq_cache_complexity_strassen`` vs the classical Q) at the queried
device params — "strassen" (the paper's Type-2 Depth-n-MM exemplar,
W = n^2.807) for square, pow2-friendly, fp32/bf16 shapes above the modeled
crossover edge (~sqrt M), "classical" otherwise — plus the recursion
``cutoff`` beneath which ``strassen_matmul``'s 7-product quadrant schedule
leaves dispatch to the Morton-ordered ``hbp_matmul`` tile kernel with f32
accumulation preserved through the combination tree.  The registry's
``matmul`` entry (``strassen_matmul.matmul``) resolves the variant at
dispatch and registers a custom VJP (dA = g Bᵀ, dB = Aᵀ g, each
re-planned for its own shape), so model matmuls (``models.common``'s
``gated_mlp`` / ``logits_matmul`` behind ``RunOptions.matmul_impl``) route
through the kernels under training and serving alike.  Autotune v3 keys
carry the planner-selected backend and its search covers backend, cutoff,
and the ``morton`` schedule flag alongside the tile ladder, so the
*measured* crossover can overrule the modeled one per device.

Tuning
------
``autotune`` closes the measure→persist→replay loop over the planner: the
analytic plans stay the source of truth, but measured winners (searched on a
power-of-two ladder around the analytic point, filtered by the costmodel
envelope and each kernel's divisibility constraints) are persisted per
``(device_kind, op, shape_class, dtype, semantic flags)`` as JSON under
``REPRO_TUNE_DIR`` (default ``~/.cache/repro/autotune``) and overlaid at
dispatch time.  Attention keys its causal/window kwargs and a derived
decode marker, so masking regimes never share a measured optimum; tables
are stamped with ``jax.__version__`` and a stamp mismatch (toolchain
upgrade) reads as a cold cache.  The
``REPRO_AUTOTUNE`` knob (mirrored by ``RunOptions.autotune``, resolved in
``planner.resolve_run_options`` and pinned by the launchers at startup)
selects among three modes:

  * ``off``    — analytic plans only; the default for bare dispatch so
    benchmarks and tests see the pure planner unless they opt in;
  * ``replay`` — overlay persisted measurements; a cold cache is a no-op;
    the launchers' startup default;
  * ``search`` — replay, plus a table miss on concrete (non-traced) arrays
    triggers an in-line timed search whose winner is persisted.

``benchmarks/autotune.py`` populates tables across a shape sweep;
``benchmarks/bench_kernels.py`` reports the resulting ``pallas_tuned_us``
next to the fixed/planned arms.  Kernel signatures stay oblivious: tuning
never adds a knob to a kernel, it only picks values for the existing ones.

Kernel modules (``bp_scan``, ``hbp_matmul``, ``strassen_matmul``,
``bi_transpose``, ``flash_attention``, ``bi_fft``) stay importable directly
for tests and experiments; ``ref`` holds the pure-jnp oracles.
"""
from repro.kernels import autotune, morton, planner, ref, registry
from repro.kernels.bi_fft import bi_fft
from repro.kernels.bi_transpose import bi_transpose
from repro.kernels.bp_scan import bp_scan
from repro.kernels.flash_attention import flash_attention
from repro.kernels.hbp_matmul import hbp_matmul
from repro.kernels.registry import dispatch
from repro.kernels.strassen_matmul import strassen_matmul

__all__ = [
    "autotune",
    "morton",
    "planner",
    "ref",
    "registry",
    "dispatch",
    "bp_scan",
    "bi_transpose",
    "bi_fft",
    "flash_attention",
    "hbp_matmul",
    "strassen_matmul",
]
