from repro.kernels import ops, ref
from repro.kernels.bp_scan import bp_scan
from repro.kernels.bi_transpose import bi_transpose
from repro.kernels.flash_attention import flash_attention
from repro.kernels.hbp_matmul import hbp_matmul

__all__ = [
    "ops",
    "ref",
    "bp_scan",
    "bi_transpose",
    "flash_attention",
    "hbp_matmul",
]
