"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def bp_scan_ref(x: jax.Array) -> jax.Array:
    return jnp.cumsum(x.astype(jnp.float32), axis=-1).astype(x.dtype)


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32)).astype(a.dtype)


def transpose_ref(x: jax.Array) -> jax.Array:
    return x.T


def fft_ref(x: jax.Array) -> jax.Array:
    """DFT along the last axis (complex64)."""
    return jnp.fft.fft(x.astype(jnp.complex64), axis=-1)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        q_offset=None, kv_len=None) -> jax.Array:
    """q, k, v: (bh, s, hd).  ``q_offset`` places query row i at absolute
    position ``q_offset + i`` (keys at 0..sk-1); ``kv_len`` masks keys at
    positions >= it.  Rows with every key masked return zeros (matching the
    kernel's ``l_safe`` guard) rather than a uniform average of v."""
    bh, sq, hd = q.shape
    sk = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    qoff = 0 if q_offset is None else jnp.asarray(q_offset, jnp.int32).reshape(())
    qp = qoff + jnp.arange(sq)[:, None]
    kp = jnp.arange(sk)[None, :]
    ok = jnp.ones((sq, sk), bool)
    if kv_len is not None:
        ok &= kp < jnp.asarray(kv_len, jnp.int32).reshape(())
    if causal:
        ok &= kp <= qp
    if window > 0:
        ok &= kp > qp - window
    s = jnp.where(ok[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(ok.any(axis=-1)[None, :, None], p, 0.0)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
