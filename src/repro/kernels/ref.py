"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def bp_scan_ref(x: jax.Array) -> jax.Array:
    return jnp.cumsum(x.astype(jnp.float32), axis=-1).astype(x.dtype)


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32)).astype(a.dtype)


def transpose_ref(x: jax.Array) -> jax.Array:
    return x.T


def fft_ref(x: jax.Array) -> jax.Array:
    """DFT along the last axis (complex64)."""
    return jnp.fft.fft(x.astype(jnp.complex64), axis=-1)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        q_offset=None, kv_len=None, n_heads=None,
                        k_scale=None, v_scale=None) -> jax.Array:
    """q: (bh, sq, hd); k, v: (kbh, sk, hd).  ``q_offset`` places query row i
    at absolute position ``q_offset + i`` (keys at 0..sk-1); ``kv_len`` masks
    keys at positions >= it.  Rows with every key masked return zeros
    (matching the kernel's ``l_safe`` guard) rather than a uniform average
    of v.

    Native-GQA twin of the kernel: ``kbh`` may be ``bh / n_rep`` with
    ``n_heads`` the per-batch query head count (batch-major fold, head =
    kv_head * n_rep + rep).  ``k_scale``/``v_scale`` (f32 ``(kbh,)``)
    dequantize an int8 k/v per KV batch-head before the scores.

    ``q_offset``/``kv_len`` also accept per-row vectors ``(rows,)`` with
    ``rows`` dividing ``bh`` (the continuous-batching contract): each lane
    of ``bh // rows`` consecutive batch-heads masks its own positions."""
    bh, sq, hd = q.shape
    kbh, sk = k.shape[0], k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if k_scale is not None:
        kf = kf * jnp.asarray(k_scale, jnp.float32).reshape(kbh, 1, 1)
    if v_scale is not None:
        vf = vf * jnp.asarray(v_scale, jnp.float32).reshape(kbh, 1, 1)
    if kbh != bh:
        # grouped: q (b, kvh, n_rep, sq, hd) against k/v (b, kvh, sk, hd)
        n_rep = bh // kbh
        h = n_heads
        assert h is not None and h % n_rep == 0 and bh % h == 0, (bh, kbh, h)
        b, kvh = bh // h, h // n_rep
        qg = q.astype(jnp.float32).reshape(b, kvh, n_rep, sq, hd)
        kg = kf.reshape(b, kvh, sk, hd)
        s = jnp.einsum("bgrqd,bgkd->bgrqk", qg, kg) * scale
    else:
        s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), kf) * scale
    qoffs = jnp.asarray(0 if q_offset is None else q_offset,
                        jnp.int32).reshape(-1)
    kvlens = (None if kv_len is None
              else jnp.asarray(kv_len, jnp.int32).reshape(-1))
    rows = max(qoffs.shape[0], 1 if kvlens is None else kvlens.shape[0])
    assert bh % rows == 0, (bh, rows)
    qp = (jnp.broadcast_to(qoffs, (rows,))[:, None, None]
          + jnp.arange(sq)[None, :, None])
    kp = jnp.arange(sk)[None, None, :]
    ok = jnp.ones((rows, sq, sk), bool)
    if kvlens is not None:
        ok &= kp < jnp.broadcast_to(kvlens, (rows,))[:, None, None]
    if causal:
        ok &= kp <= qp
    if window > 0:
        ok &= kp > qp - window
    # each lane covers bh // rows consecutive batch-heads of the fold
    okb = jnp.repeat(ok, bh // rows, axis=0)      # (bh, sq, sk)
    any_ok = okb.any(axis=-1)                     # (bh, sq)
    if kbh != bh:
        s = jnp.where(okb.reshape(b, kvh, n_rep, sq, sk), s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        p = jnp.where(any_ok.reshape(b, kvh, n_rep, sq)[..., None], p, 0.0)
        vg = vf.reshape(b, kvh, sk, hd)
        out = jnp.einsum("bgrqk,bgkd->bgrqd", p, vg)
        return out.reshape(bh, sq, hd).astype(q.dtype)
    s = jnp.where(okb, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(any_ok[:, :, None], p, 0.0)
    return jnp.einsum("bqk,bkd->bqd", p, vf).astype(q.dtype)
