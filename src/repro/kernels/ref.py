"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def bp_scan_ref(x: jax.Array) -> jax.Array:
    return jnp.cumsum(x.astype(jnp.float32), axis=-1).astype(x.dtype)


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32)).astype(a.dtype)


def transpose_ref(x: jax.Array) -> jax.Array:
    return x.T


def fft_ref(x: jax.Array) -> jax.Array:
    """DFT along the last axis (complex64)."""
    return jnp.fft.fft(x.astype(jnp.complex64), axis=-1)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        q_offset=None, kv_len=None, n_heads=None,
                        k_scale=None, v_scale=None) -> jax.Array:
    """q: (bh, sq, hd); k, v: (kbh, sk, hd).  ``q_offset`` places query row i
    at absolute position ``q_offset + i`` (keys at 0..sk-1); ``kv_len`` masks
    keys at positions >= it.  Rows with every key masked return zeros
    (matching the kernel's ``l_safe`` guard) rather than a uniform average
    of v.

    Native-GQA twin of the kernel: ``kbh`` may be ``bh / n_rep`` with
    ``n_heads`` the per-batch query head count (batch-major fold, head =
    kv_head * n_rep + rep).  ``k_scale``/``v_scale`` (f32 ``(kbh,)``)
    dequantize an int8 k/v per KV batch-head before the scores."""
    bh, sq, hd = q.shape
    kbh, sk = k.shape[0], k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if k_scale is not None:
        kf = kf * jnp.asarray(k_scale, jnp.float32).reshape(kbh, 1, 1)
    if v_scale is not None:
        vf = vf * jnp.asarray(v_scale, jnp.float32).reshape(kbh, 1, 1)
    if kbh != bh:
        # grouped: q (b, kvh, n_rep, sq, hd) against k/v (b, kvh, sk, hd)
        n_rep = bh // kbh
        h = n_heads
        assert h is not None and h % n_rep == 0 and bh % h == 0, (bh, kbh, h)
        b, kvh = bh // h, h // n_rep
        qg = q.astype(jnp.float32).reshape(b, kvh, n_rep, sq, hd)
        kg = kf.reshape(b, kvh, sk, hd)
        s = jnp.einsum("bgrqd,bgkd->bgrqk", qg, kg) * scale
    else:
        s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), kf) * scale
    qoff = 0 if q_offset is None else jnp.asarray(q_offset, jnp.int32).reshape(())
    qp = qoff + jnp.arange(sq)[:, None]
    kp = jnp.arange(sk)[None, :]
    ok = jnp.ones((sq, sk), bool)
    if kv_len is not None:
        ok &= kp < jnp.asarray(kv_len, jnp.int32).reshape(())
    if causal:
        ok &= kp <= qp
    if window > 0:
        ok &= kp > qp - window
    any_ok = ok.any(axis=-1)  # (sq,)
    if kbh != bh:
        s = jnp.where(ok[None, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        p = jnp.where(any_ok[None, None, None, :, None], p, 0.0)
        vg = vf.reshape(b, kvh, sk, hd)
        out = jnp.einsum("bgrqk,bgkd->bgrqd", p, vg)
        return out.reshape(bh, sq, hd).astype(q.dtype)
    s = jnp.where(ok[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(any_ok[None, :, None], p, 0.0)
    return jnp.einsum("bqk,bkd->bqd", p, vf).astype(q.dtype)
