"""Flash attention Pallas kernel — the BP online-softmax reduce as a TPU
kernel (the kernel twin of ``repro.models.common.attention_blockwise``).

Grid: (batch*heads * nq, nk) with the KV loop innermost; running (m, l, acc)
live in VMEM scratch (the BP up-pass combine state); causal/sliding-window
masking from block offsets via iota.  The flattened outer (bh, nq) grid is
decoded through ``repro.kernels.morton.grid_decode`` — Morton (BI) order
when square power-of-two, so consecutive outer steps revisit the same KV
panels (the §3.2 block-sharing argument applied to the schedule); row-major
fallback otherwise.  The KV sweep for one (b, q) pair always stays
contiguous (the scratch accumulator requires it).

Cached decode (serving) is covered by two kwargs:

``q_offset``
    Absolute position of query row 0 (``q`` row ``i`` sits at position
    ``q_offset + i``; keys sit at positions ``0..sk-1``).  May be a traced
    scalar — the decode loop's ``pos`` — passed to the kernel through SMEM,
    so per-step offsets never recompile.

``kv_len``
    Number of valid KV slots; keys at or beyond it are masked.  A *static*
    ``kv_len`` shrinks the KV grid itself (the planner-aware grid: only
    ``ceil(kv_len / kv_block)`` blocks are ever visited); a traced one keeps
    the full grid and skips dead blocks with ``pl.when`` (no recompiles
    across decode steps).

Both kwargs also accept a PER-ROW vector of shape ``(rows,)`` where ``rows``
divides the folded batch-head count (``rows`` = the batch under the
batch-major head fold) — the continuous-batching contract: each batch lane
carries its own decode position and its own valid cache prefix, so cache
slots at different depths coexist in one kernel launch.  The vectors live
in SMEM; each grid step indexes its lane's scalars (``r = bh // hpb``), and
a traced vector keeps the no-recompile property across decode steps of
varying per-row lengths.  A *concrete* (numpy) vector still shrinks the KV
grid to ``ceil(max(kv_len) / kv_block)`` blocks; shorter lanes skip their
dead blocks with ``pl.when``.  A lane with ``kv_len == 0`` (nothing valid
yet) emits zeros through the ``l_safe`` guard.

A query row with every key masked (possible when ``window > 0`` and
``q_offset`` outruns ``kv_len``) returns zeros — masked probabilities are
explicitly zeroed so the ``l`` accumulator stays 0 and the ``l_safe`` guard
emits 0, matching ``ref.flash_attention_ref``.

The kernel carries a custom VJP (registered per static config): the
recomputation-style flash backward — forward also emits the per-row LSE,
backward recomputes P per block from (q, k, lse) and produces dq (KV-sweep
grid) and dk/dv (q-sweep grid) without ever materializing an O(sq*sk)
tensor.  A pallas-resolving execution policy therefore no longer needs to
route attention around the kernel under autodiff.

GQA is kernel-native: callers pass K/V at their *native* head count and the
kv ``index_map`` routes each query head's grid step straight into its group's
KV row (``bh -> (bh // h) * kvh + (bh % h) // n_rep`` under the batch-major
head fold) — the cache-sized ``repeat_kv`` materialization the adapter used
to pay per decode step is gone; every head in a group re-reads the *same*
blocks, which is exactly the paper's O(1)-block-sharing discipline.  The
caller declares its per-batch query head count via ``n_heads`` whenever
``k.shape[0] < q.shape[0]``.  The backward keeps the no-copy contract: dq
runs on the forward grid with the same kv index map, and dk/dv extend the
transposed KV-outer grid's inner axis to ``n_rep * nq`` — each KV tile's
scratch accumulates the contributions of all ``n_rep`` query heads in its
group before emitting, so the group sum happens in VMEM, never through an
O(n_rep)-sized intermediate.

Quantized KV (serving): int8 ``k``/``v`` with per-(batch, kv-head) f32
scales (``k_scale``/``v_scale``, shape ``(kbh,)``) dequantize *inside* the
kernel block load — the cache streams at 1/4 the f32 block traffic and the
f32 copy never exists outside VMEM.  The quantized path is forward-only
(decode never differentiates; int8 carries no tangent).

``q_block=None`` / ``kv_block=None`` (the defaults) plan the blocks from
the queried device via ``repro.kernels.planner`` (per-dtype envelopes: an
int8 KV stream budgets a deeper panel); ragged sequence lengths snap each
block down to the largest divisor of its axis instead of asserting, and a
degenerate snap (prime-ish lengths) falls back to the jnp oracle.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.morton import grid_decode

NEG_INF = -1e30


def _kv_index(b_, *, h: int, kvh: int, n_rep: int):
    """Native-KV-head GQA index: query batch-head ``b_`` (batch-major fold,
    head = kv_head * n_rep + rep) -> its group's KV batch-head row.  Plain
    integer arithmetic, works on traced grid indices."""
    if n_rep == 1:
        return b_
    return (b_ // h) * kvh + (b_ % h) // n_rep


def _mask(qoff, kvlen, qi, kb, *, causal, window, q_block, kv_block,
          full_len):
    """(q_block, kv_block) validity mask from block coordinates and the SMEM
    scalars; shared by the forward and both backward kernels so the three
    recomputations of P agree bit-for-bit.  ``full_len`` (static: kv_len
    covers the whole KV axis) drops the validity term; with no causal/window
    masking either, returns None — the caller skips masking entirely, so
    plain self-attention pays nothing for the decode machinery."""
    if full_len and not causal and window <= 0:
        return None
    q_pos = qoff + qi * q_block + jax.lax.broadcasted_iota(
        jnp.int32, (q_block, kv_block), 0)
    k_pos = kb * kv_block + jax.lax.broadcasted_iota(
        jnp.int32, (q_block, kv_block), 1)
    ok = None if full_len else (k_pos < kvlen)
    if causal:
        c = k_pos <= q_pos
        ok = c if ok is None else ok & c
    if window > 0:
        w = k_pos > q_pos - window
        ok = w if ok is None else ok & w
    return ok


def _run_kv_block(body, kb, kvlen, *, kv_block, full_len):
    """Run ``body`` for one KV block, skipping blocks past ``kv_len`` via
    ``pl.when`` — unless the static config says every block is live."""
    if full_len:
        body()
    else:
        pl.when(kb * kv_block < kvlen)(body)


def _flash_kernel(qoff_ref, kvlen_ref, *refs, scale: float, causal: bool,
                  window: int, q_block: int, kv_block: int, nk: int,
                  full_len: bool, decode, quantized: bool, h: int, kvh: int,
                  n_rep: int, hpb: int):
    if quantized:
        (kscale_ref, vscale_ref, q_ref, k_ref, v_ref,
         o_ref, lse_ref, m_ref, l_ref, acc_ref) = refs
    else:
        q_ref, k_ref, v_ref, o_ref, lse_ref, m_ref, l_ref, acc_ref = refs
        kscale_ref = vscale_ref = None
    kb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    b_, qi = decode(pl.program_id(0))
    # per-row decode state: lane r = b_ // hpb (hpb = batch-heads per row;
    # rows == 1 makes this the old shared-scalar read)
    qoff, kvlen = qoff_ref[b_ // hpb], kvlen_ref[b_ // hpb]

    def _body():
        q = q_ref[0].astype(jnp.float32)  # (q_block, hd)
        k = k_ref[0].astype(jnp.float32)  # (kv_block, hd)
        v = v_ref[0].astype(jnp.float32)
        if quantized:
            # per-(batch, kv-head) dequant at the block load: the int8 cache
            # is the only thing that ever crossed slow memory
            kvb = _kv_index(b_, h=h, kvh=kvh, n_rep=n_rep)
            k = k * kscale_ref[kvb]
            v = v * vscale_ref[kvb]

        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        ok = _mask(qoff, kvlen, qi, kb, causal=causal, window=window,
                   q_block=q_block, kv_block=kv_block, full_len=full_len)
        if ok is not None:
            s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        if ok is not None:
            # explicit zero at masked slots: when a row is fully masked m_new
            # is still NEG_INF and exp(s - m_new) would be 1, silently
            # averaging v
            p = jnp.where(ok, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    _run_kv_block(_body, kb, kvlen, kv_block=kv_block, full_len=full_len)

    @pl.when(kb == nk - 1)
    def _emit():
        l_safe = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l_safe[:, None]).astype(o_ref.dtype)
        lse_ref[0] = m_ref[...] + jnp.log(l_safe)


def _probs_from_lse(s, ok, lse):
    """exp(s - lse) = softmax probs (lse folds the l normalizer); the
    explicit zero guards fully-masked rows where lse ~ NEG_INF."""
    p = jnp.exp(s - lse[:, None])
    return p if ok is None else jnp.where(ok, p, 0.0)


def _bwd_dq_kernel(qoff_ref, kvlen_ref, q_ref, k_ref, v_ref, g_ref, lse_ref,
                   delta_ref, dq_ref, dq_acc, *, scale: float, causal: bool,
                   window: int, q_block: int, kv_block: int, nk: int,
                   full_len: bool, decode, hpb: int):
    """dq = sum over KV blocks of (P * (dO K^T... ) ) — same grid shape and
    schedule as the forward, accumulating dq in scratch.  GQA needs no body
    change here: the kv index map hands each query head its group's native
    KV blocks."""
    kb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    b_, qi = decode(pl.program_id(0))
    qoff, kvlen = qoff_ref[b_ // hpb], kvlen_ref[b_ // hpb]

    def _body():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        g = g_ref[0].astype(jnp.float32)
        lse = lse_ref[0]      # (q_block,) f32
        delta = delta_ref[0]  # (q_block,) f32 rowsum(dO * O)

        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        ok = _mask(qoff, kvlen, qi, kb, causal=causal, window=window,
                   q_block=q_block, kv_block=kv_block, full_len=full_len)
        if ok is not None:
            s = jnp.where(ok, s, NEG_INF)
        p = _probs_from_lse(s, ok, lse)
        dp = jnp.dot(g, v.T, preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        dq_acc[...] += jnp.dot(ds, k, preferred_element_type=jnp.float32)

    _run_kv_block(_body, kb, kvlen, kv_block=kv_block, full_len=full_len)

    @pl.when(kb == nk - 1)
    def _emit():
        dq_ref[0] = dq_acc[...].astype(dq_ref.dtype)


def _bwd_dkv_kernel(qoff_ref, kvlen_ref, q_ref, k_ref, v_ref, g_ref, lse_ref,
                    delta_ref, dk_ref, dv_ref, dk_acc, dv_acc, *, scale: float,
                    causal: bool, window: int, q_block: int, kv_block: int,
                    nq: int, n_rep: int, full_len: bool, decode, khpb: int):
    """dk/dv: the transposed sweep — outer grid over (kbh, nk) *native* KV
    tiles, inner loop over ``n_rep * nq`` (every q block of every query head
    in this KV head's group), accumulating (kv_block, hd) dk/dv in scratch —
    the GQA group sum lives in the accumulator, no repeated KV ever exists.
    KV blocks beyond ``kv_len`` (and, under causal masking, q blocks entirely
    before the KV block) skip the matmuls but still emit their zeros."""
    j = pl.program_id(1)
    qi = j % nq if n_rep > 1 else j  # inner axis = (rep, qi), rep-major

    @pl.when(j == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    b_, kb = decode(pl.program_id(0))
    # lane index through the KV batch-head fold (khpb = kv batch-heads/row)
    qoff, kvlen = qoff_ref[b_ // khpb], kvlen_ref[b_ // khpb]

    live = None if full_len else (kb * kv_block < kvlen)
    if causal:
        # max q position in this q block >= min k position in this kv block
        c = qoff + (qi + 1) * q_block - 1 >= kb * kv_block
        live = c if live is None else live & c

    def _body():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        g = g_ref[0].astype(jnp.float32)
        lse = lse_ref[0]
        delta = delta_ref[0]

        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        ok = _mask(qoff, kvlen, qi, kb, causal=causal, window=window,
                   q_block=q_block, kv_block=kv_block, full_len=full_len)
        if ok is not None:
            s = jnp.where(ok, s, NEG_INF)
        p = _probs_from_lse(s, ok, lse)
        dv_acc[...] += jnp.dot(p.T, g, preferred_element_type=jnp.float32)
        dp = jnp.dot(g, v.T, preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        dk_acc[...] += jnp.dot(ds.T, q, preferred_element_type=jnp.float32)

    if live is None:
        _body()
    else:
        pl.when(live)(_body)

    @pl.when(j == n_rep * nq - 1)
    def _emit():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


def _gqa_geometry(q, k, n_heads: Optional[int]):
    """(h, kvh, n_rep) from the folded shapes and the caller's declared
    per-batch query head count."""
    bh, kbh = q.shape[0], k.shape[0]
    if bh == kbh:
        return bh, kbh, 1
    if n_heads is None:
        raise ValueError(
            f"native-GQA flash_attention: k has {kbh} batch-heads vs q's "
            f"{bh}; pass n_heads (query heads per batch) so the kv index "
            "map can decompose the batch-head fold")
    if bh % kbh != 0:
        raise ValueError(f"q batch-heads {bh} not a multiple of kv "
                         f"batch-heads {kbh}")
    n_rep = bh // kbh
    if n_heads % n_rep != 0 or bh % n_heads != 0:
        raise ValueError(f"n_heads={n_heads} incompatible with q/kv "
                         f"batch-heads ({bh}, {kbh})")
    return n_heads, n_heads // n_rep, n_rep


def _fwd_call(q, k, v, qoff, kvlen, kscale, vscale, *, causal, window,
              q_block, kv_block, nk_run, full_len, n_heads, rows, interpret):
    """Forward pallas_call: returns (out, lse)."""
    bh, sq, hd = q.shape
    nq = sq // q_block
    scale = 1.0 / math.sqrt(hd)
    h, kvh, n_rep = _gqa_geometry(q, k, n_heads)
    hpb = bh // rows  # batch-heads per decode lane (rows == 1: one lane)
    quantized = kscale is not None
    # BI order over the flattened (bh, nq) outer grid; the KV dim stays the
    # trailing (contiguous) grid axis so the scratch combine is well-defined.
    decode = grid_decode(bh, nq)
    smem = pl.BlockSpec(memory_space=pltpu.SMEM)

    def q_map(g, j):
        b, i = decode(g)
        return (b, i, 0)

    def kv_map(g, j):
        b, _ = decode(g)
        return (_kv_index(b, h=h, kvh=kvh, n_rep=n_rep), j, 0)

    def row_map(g, j):
        b, i = decode(g)
        return (b, i)

    in_specs = [smem, smem]
    operands = [qoff, kvlen]
    if quantized:
        in_specs += [smem, smem]
        operands += [kscale, vscale]
    in_specs += [pl.BlockSpec((1, q_block, hd), q_map),
                 pl.BlockSpec((1, kv_block, hd), kv_map),
                 pl.BlockSpec((1, kv_block, hd), kv_map)]
    operands += [q, k, v]

    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          window=window, q_block=q_block, kv_block=kv_block,
                          nk=nk_run, full_len=full_len, decode=decode,
                          quantized=quantized, h=h, kvh=kvh, n_rep=n_rep,
                          hpb=hpb),
        grid=(bh * nq, nk_run),
        in_specs=in_specs,
        out_specs=[pl.BlockSpec((1, q_block, hd), q_map),
                   pl.BlockSpec((1, q_block), row_map)],
        out_shape=[jax.ShapeDtypeStruct((bh, sq, hd), q.dtype),
                   jax.ShapeDtypeStruct((bh, sq), jnp.float32)],
        scratch_shapes=[
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block, hd), jnp.float32),
        ],
        interpret=interpret,
    )(*operands)


def _bwd_call(q, k, v, qoff, kvlen, out, lse, g, *, causal, window, q_block,
              kv_block, nk_run, full_len, n_heads, rows, interpret):
    """Backward pallas_calls: dq over the forward's (q-outer, kv-inner) grid,
    dk/dv over the transposed (kv-outer, (rep, q)-inner) grid at the native
    KV head count."""
    bh, sq, hd = q.shape
    sk = k.shape[1]
    kbh = k.shape[0]
    nq = sq // q_block
    nk_full = sk // kv_block
    scale = 1.0 / math.sqrt(hd)
    h, kvh, n_rep = _gqa_geometry(q, k, n_heads)
    hpb = bh // rows
    khpb = kbh // rows  # kv batch-heads per decode lane
    delta = jnp.sum(g.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)
    smem = pl.BlockSpec(memory_space=pltpu.SMEM)

    dec_q = grid_decode(bh, nq)

    def q_map(g_, j):
        b, i = dec_q(g_)
        return (b, i, 0)

    def kv_map(g_, j):
        b, _ = dec_q(g_)
        return (_kv_index(b, h=h, kvh=kvh, n_rep=n_rep), j, 0)

    def row_map(g_, j):
        b, i = dec_q(g_)
        return (b, i)

    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, scale=scale, causal=causal,
                          window=window, q_block=q_block, kv_block=kv_block,
                          nk=nk_run, full_len=full_len, decode=dec_q,
                          hpb=hpb),
        grid=(bh * nq, nk_run),
        in_specs=[smem, smem,
                  pl.BlockSpec((1, q_block, hd), q_map),
                  pl.BlockSpec((1, kv_block, hd), kv_map),
                  pl.BlockSpec((1, kv_block, hd), kv_map),
                  pl.BlockSpec((1, q_block, hd), q_map),
                  pl.BlockSpec((1, q_block), row_map),
                  pl.BlockSpec((1, q_block), row_map)],
        out_specs=pl.BlockSpec((1, q_block, hd), q_map),
        out_shape=jax.ShapeDtypeStruct((bh, sq, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((q_block, hd), jnp.float32)],
        interpret=interpret,
    )(qoff, kvlen, q, k, v, g, lse, delta)

    # transposed grid at the NATIVE kv head count: the full nk (not the
    # shrunk run) so every dk/dv block is written — dead blocks emit the
    # zeros their masked keys earn.  The inner axis covers (rep, q block):
    # each KV tile accumulates its whole group's contributions in scratch
    dec_kv = grid_decode(kbh, nk_full)

    def _qbh(b, j):
        # query batch-head for kv batch-head ``b`` and inner index ``j``
        if n_rep == 1:
            return b
        return (b // kvh) * h + (b % kvh) * n_rep + j // nq

    def kv_map_t(g_, j):
        b, i = dec_kv(g_)
        return (b, i, 0)

    def q_map_t(g_, j):
        b, _ = dec_kv(g_)
        return (_qbh(b, j), j % nq if n_rep > 1 else j, 0)

    def row_map_t(g_, j):
        b, _ = dec_kv(g_)
        return (_qbh(b, j), j % nq if n_rep > 1 else j)

    dk, dv = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, scale=scale, causal=causal,
                          window=window, q_block=q_block, kv_block=kv_block,
                          nq=nq, n_rep=n_rep, full_len=full_len,
                          decode=dec_kv, khpb=khpb),
        grid=(kbh * nk_full, n_rep * nq),
        in_specs=[smem, smem,
                  pl.BlockSpec((1, q_block, hd), q_map_t),
                  pl.BlockSpec((1, kv_block, hd), kv_map_t),
                  pl.BlockSpec((1, kv_block, hd), kv_map_t),
                  pl.BlockSpec((1, q_block, hd), q_map_t),
                  pl.BlockSpec((1, q_block), row_map_t),
                  pl.BlockSpec((1, q_block), row_map_t)],
        out_specs=[pl.BlockSpec((1, kv_block, hd), kv_map_t),
                   pl.BlockSpec((1, kv_block, hd), kv_map_t)],
        out_shape=[jax.ShapeDtypeStruct((kbh, sk, hd), k.dtype),
                   jax.ShapeDtypeStruct((kbh, sk, hd), v.dtype)],
        scratch_shapes=[pltpu.VMEM((kv_block, hd), jnp.float32),
                        pltpu.VMEM((kv_block, hd), jnp.float32)],
        interpret=interpret,
    )(qoff, kvlen, q, k, v, g, lse, delta)
    return dq, dk, dv


@functools.lru_cache(maxsize=None)
def _flash_fn(causal: bool, window: int, q_block: int, kv_block: int,
              nk_run: int, full_len: bool, n_heads: Optional[int],
              rows: int, quantized: bool, interpret: bool):
    """custom-VJP flash attention for one static config, jitted so repeated
    eager calls (tests, benchmarks) reuse the lowered kernel.  The quantized
    (int8 KV + scales) variant is forward-only."""
    cfg = dict(causal=causal, window=window, q_block=q_block,
               kv_block=kv_block, nk_run=nk_run, full_len=full_len,
               n_heads=n_heads, rows=rows, interpret=interpret)

    if quantized:
        def fa_quant(q, k, v, qoff, kvlen, kscale, vscale):
            out, _ = _fwd_call(q, k, v, qoff, kvlen, kscale, vscale, **cfg)
            return out

        return jax.jit(fa_quant)

    @jax.custom_vjp
    def fa(q, k, v, qoff, kvlen):
        out, _ = _fwd_call(q, k, v, qoff, kvlen, None, None, **cfg)
        return out

    def fa_fwd(q, k, v, qoff, kvlen):
        out, lse = _fwd_call(q, k, v, qoff, kvlen, None, None, **cfg)
        return out, (q, k, v, qoff, kvlen, out, lse)

    def fa_bwd(res, g):
        q, k, v, qoff, kvlen, out, lse = res
        dq, dk, dv = _bwd_call(q, k, v, qoff, kvlen, out, lse, g, **cfg)
        return dq, dk, dv, None, None

    fa.defvjp(fa_fwd, fa_bwd)
    return jax.jit(fa)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    q_offset: Optional[Union[int, jax.Array]] = None,
                    kv_len: Optional[Union[int, jax.Array]] = None,
                    q_block: Optional[int] = None,
                    kv_block: Optional[int] = None,
                    n_heads: Optional[int] = None,
                    k_scale: Optional[jax.Array] = None,
                    v_scale: Optional[jax.Array] = None,
                    interpret: bool = True) -> jax.Array:
    """q: (bh, sq, hd); k, v: (kbh, sk, hd) — heads pre-folded into batch
    (batch-major: bh = batch * heads + head).  Returns (bh, sq, hd).

    GQA is kernel-native: ``kbh`` may be ``bh / n_rep`` (K/V at their native
    head count) with ``n_heads`` declaring the per-batch query head count —
    the kv index map routes each query head's blocks to its group's KV row,
    and the backward group-sums dk/dv inside the transposed grid.  No
    caller-side repeat.

    ``q_offset`` places query row i at absolute position ``q_offset + i``
    (keys at ``0..sk-1``); ``kv_len`` masks keys at positions >= it.  Both
    accept traced scalars (decode loops never recompile); a static ``kv_len``
    additionally shrinks the KV grid to ``ceil(kv_len / kv_block)`` blocks.
    Both also accept per-row vectors of shape ``(rows,)`` with ``rows``
    dividing ``bh`` and ``kbh`` (the continuous-batching contract, see the
    module docstring): traced vectors never recompile across steps, concrete
    (list/numpy) ``kv_len`` vectors shrink the grid to the longest lane.
    ``k_scale``/``v_scale`` (f32 ``(kbh,)``, paired with an int8 ``k``/``v``)
    dequantize per KV batch-head inside the kernel; the quantized path is
    forward-only.  Otherwise differentiable w.r.t. q/k/v via the registered
    recomputation backward.
    """
    from repro.kernels import planner

    bh, sq, hd = q.shape
    sk = k.shape[1]
    if (k_scale is None) != (v_scale is None):
        raise ValueError("k_scale and v_scale must be passed together")
    quantized = k_scale is not None
    if q_block is None or kv_block is None:
        plan = planner.plan_attention(sq, sk, hd, q.dtype, kv_dtype=k.dtype)
        q_block = q_block if q_block is not None else plan["q_block"]
        kv_block = kv_block if kv_block is not None else plan["kv_block"]
    # ragged lengths snap each block to the largest divisor of its axis (the
    # planner's own plans are divisor-exact; this covers explicit overrides)
    q_block = planner.divisor_tile(sq, min(int(q_block), sq))
    kv_block = planner.divisor_tile(sk, min(int(kv_block), sk))
    # a degenerate snap (prime/odd axis -> sub-sublane tile on a long dim)
    # would run a catastrophically fine grid; take the jnp oracle instead
    if (q_block < 8 <= sq) or (kv_block < 8 <= sk):
        from repro.kernels import ref

        return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                       q_offset=q_offset, kv_len=kv_len,
                                       n_heads=n_heads, k_scale=k_scale,
                                       v_scale=v_scale)
    _gqa_geometry(q, k, n_heads)  # validate early, outside the jit
    nk_full = sk // kv_block

    def _veclen(x):
        if x is None or (isinstance(x, (int, np.integer))
                         and not isinstance(x, bool)):
            return 1
        shp = jnp.shape(x)
        if len(shp) > 1:
            raise ValueError(f"q_offset/kv_len must be scalar or 1-D, got "
                             f"shape {shp}")
        return int(shp[0]) if shp else 1

    def _concrete(x):
        """Host-known values as a numpy vector, else None (traced)."""
        if isinstance(x, (int, np.integer)) and not isinstance(x, bool):
            return np.asarray([int(x)], np.int64)
        if isinstance(x, (list, tuple, np.ndarray)):
            return np.asarray(x, np.int64).reshape(-1)
        return None

    # per-row lanes: rows = the common vector length of q_offset/kv_len
    # (scalars broadcast); each lane owns bh/rows query heads in the fold
    rows = max(_veclen(q_offset), _veclen(kv_len))
    for name, x in (("q_offset", q_offset), ("kv_len", kv_len)):
        if _veclen(x) not in (1, rows):
            raise ValueError(f"{name} has {_veclen(x)} rows, expected 1 or "
                             f"{rows}")
    if bh % rows != 0 or k.shape[0] % rows != 0:
        raise ValueError(f"per-row q_offset/kv_len of {rows} rows must "
                         f"divide the folded batch-head counts "
                         f"({bh}, {k.shape[0]})")

    static_vals = (np.asarray([sk], np.int64) if kv_len is None
                   else _concrete(kv_len))
    if static_vals is not None:
        vals = np.clip(static_vals, 0, sk)
        # grid shrinks to the longest lane; shorter lanes pl.when-skip
        static_len = int(vals.max())
        nk_run = max(-(-static_len // kv_block), 1)
        # static full coverage on EVERY lane: no validity mask — the plain
        # self-attention config compiles to the pre-decode kernel body
        full_len = int(vals.min()) >= sk
        kvlen_arr = jnp.broadcast_to(jnp.asarray(vals, jnp.int32), (rows,))
    else:
        nk_run = nk_full  # traced: full grid, pl.when skips dead blocks
        full_len = False
        kvlen_arr = jnp.broadcast_to(
            jnp.asarray(kv_len, jnp.int32).reshape(-1), (rows,))
    qoff_arr = jnp.broadcast_to(
        jnp.asarray(0 if q_offset is None else q_offset,
                    jnp.int32).reshape(-1), (rows,))

    fa = _flash_fn(bool(causal), int(window), q_block, kv_block, nk_run,
                   full_len, None if n_heads is None else int(n_heads),
                   rows, quantized, bool(interpret))
    if quantized:
        kbh = k.shape[0]
        return fa(q, k, v, qoff_arr, kvlen_arr,
                  jnp.asarray(k_scale, jnp.float32).reshape(kbh),
                  jnp.asarray(v_scale, jnp.float32).reshape(kbh))
    return fa(q, k, v, qoff_arr, kvlen_arr)
