"""Flash attention Pallas kernel — the BP online-softmax reduce as a TPU
kernel (the kernel twin of ``repro.models.common.attention_blockwise``).

Grid: (batch*heads * nq, nk) with the KV loop innermost; running (m, l, acc)
live in VMEM scratch (the BP up-pass combine state); causal/sliding-window
masking from block offsets via iota.  The flattened outer (bh, nq) grid is
decoded through ``repro.kernels.morton.grid_decode`` — Morton (BI) order
when square power-of-two, so consecutive outer steps revisit the same KV
panels (the §3.2 block-sharing argument applied to the schedule); row-major
fallback otherwise.  The KV sweep for one (b, q) pair always stays
contiguous (the scratch accumulator requires it).

Supports GQA by passing pre-repeated or per-head-group K/V slices from the
model adapter.  ``q_block=None`` / ``kv_block=None`` (the defaults) plan
the blocks from the queried device via ``repro.kernels.planner``.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.morton import grid_decode

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int, q_block: int,
                  kv_block: int, nk: int, decode):
    kb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)  # (q_block, hd)
    k = k_ref[0].astype(jnp.float32)  # (kv_block, hd)
    v = v_ref[0].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    _, qi = decode(pl.program_id(0))
    q_pos = qi * q_block + jax.lax.broadcasted_iota(
        jnp.int32, (q_block, kv_block), 0)
    k_pos = kb * kv_block + jax.lax.broadcasted_iota(
        jnp.int32, (q_block, kv_block), 1)
    ok = jnp.ones((q_block, kv_block), jnp.bool_)
    if causal:
        ok &= k_pos <= q_pos
    if window > 0:
        ok &= k_pos > q_pos - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kb == nk - 1)
    def _emit():
        l_safe = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l_safe[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_block",
                                             "kv_block", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    q_block: Optional[int] = None,
                    kv_block: Optional[int] = None,
                    interpret: bool = True) -> jax.Array:
    """q: (bh, sq, hd); k, v: (bh, sk, hd) — heads pre-folded into batch
    (GQA repeat handled by the caller).  Returns (bh, sq, hd)."""
    bh, sq, hd = q.shape
    sk = k.shape[1]
    if q_block is None or kv_block is None:
        from repro.kernels import planner

        plan = planner.plan_attention(sq, sk, hd, q.dtype)
        q_block = q_block if q_block is not None else plan["q_block"]
        kv_block = kv_block if kv_block is not None else plan["kv_block"]
    q_block = min(q_block, sq)
    kv_block = min(kv_block, sk)
    assert sq % q_block == 0 and sk % kv_block == 0
    nq, nk = sq // q_block, sk // kv_block
    scale = 1.0 / math.sqrt(hd)

    # BI order over the flattened (bh, nq) outer grid; the KV dim stays the
    # trailing (contiguous) grid axis so the scratch combine is well-defined.
    decode = grid_decode(bh, nq)

    def q_map(g, j):
        b, i = decode(g)
        return (b, i, 0)

    def kv_map(g, j):
        b, _ = decode(g)
        return (b, j, 0)

    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          window=window, q_block=q_block, kv_block=kv_block,
                          nk=nk, decode=decode),
        grid=(bh * nq, nk),
        in_specs=[
            pl.BlockSpec((1, q_block, hd), q_map),
            pl.BlockSpec((1, kv_block, hd), kv_map),
            pl.BlockSpec((1, kv_block, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, q_block, hd), q_map),
        out_shape=jax.ShapeDtypeStruct((bh, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
