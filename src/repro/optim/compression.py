"""Int8 gradient compression with error feedback — for cross-pod (DCN) DP
gradient sync, where link bandwidth is the binding constraint.

Scheme: per-tensor symmetric int8 quantization q = round(g / s), s =
max|g| / 127, with an error-feedback residual carried in the optimizer state
so quantization error does not bias the update (Karimireddy et al., 2019).

Paper tie-in: compression is a *block-miss* optimization in the paper's
vocabulary — it reduces the bytes per shared block crossing the slowest
"cache boundary" (the pod interconnect).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def compress_int8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Returns (q int8, scale fp32)."""
    g32 = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_tree(grads: Any) -> tuple[Any, Any]:
    qs = jax.tree.map(lambda g: compress_int8(g)[0], grads)
    scales = jax.tree.map(lambda g: compress_int8(g)[1], grads)
    return qs, scales


def ef_compress(grads: Any, residual: Any) -> tuple[Any, Any, Any]:
    """Error-feedback compression: returns (q, scales, new_residual)."""

    def one(g, r):
        x = g.astype(jnp.float32) + r
        q, s = compress_int8(x)
        back = decompress_int8(q, s)
        return q, s, x - back

    flat_g, td = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    qs, ss, rs = [], [], []
    for g, r in zip(flat_g, flat_r):
        q, s, nr = one(g, r)
        qs.append(q)
        ss.append(s)
        rs.append(nr)
    return jax.tree.unflatten(td, qs), jax.tree.unflatten(td, ss), jax.tree.unflatten(td, rs)
