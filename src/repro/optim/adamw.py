"""Decoupled AdamW with bf16 params + fp32 master copies (mixed precision).

Paper tie-in (limited access): each optimizer-state shard has exactly one
writer (the device owning the shard under the PWS planner's FSDP layout), so
updates never contend on a block — the optimizer step is a pure BP map.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params: Any) -> dict:
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {
        "step": jnp.zeros((), jnp.int32),
        "master": master,
        "m": zeros,
        "v": jax.tree.map(jnp.copy, zeros),
    }


def global_norm(tree: Any) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def adamw_update(
    params: Any,
    grads: Any,
    opt_state: dict,
    cfg: AdamWConfig,
    lr_schedule: Optional[Callable[[jax.Array], jax.Array]] = None,
) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = cfg.lr if lr_schedule is None else lr_schedule(step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) if cfg.grad_clip else 1.0

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(master, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * master
        return master - lr * delta, m, v

    flat_master, treedef = jax.tree.flatten(opt_state["master"])
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    new_master, new_m, new_v = [], [], []
    for ma, g, m, v in zip(flat_master, flat_g, flat_m, flat_v):
        a, b, c = upd(ma, g, m, v)
        new_master.append(a)
        new_m.append(b)
        new_v.append(c)
    master = jax.tree.unflatten(treedef, new_master)
    new_params = jax.tree.map(lambda ma, p: ma.astype(p.dtype), master, params)
    new_state = {
        "step": step,
        "master": master,
        "m": jax.tree.unflatten(treedef, new_m),
        "v": jax.tree.unflatten(treedef, new_v),
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": jnp.asarray(lr)}
