"""Deterministic synthetic LM data pipeline.

Production shape: seeded shard-deterministic token sampling (each (step,
host) pair regenerates identical data — the property fault-tolerant restart
relies on), sequence packing of variable-length documents, prefetch via a
background thread, and modality-stub extras for VLM/audio archs.

Determinism contract: ``batch_at(step)`` is a pure function of (seed, step),
so a restarted job replays the exact token stream without coordination —
the data-plane half of checkpoint/restart.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    global_batch: int = 8
    seq_len: int = 256
    # synthetic document length distribution (for packing)
    mean_doc_len: int = 180
    pad_id: int = 0
    bos_id: int = 1
    eos_id: int = 2


class SyntheticLMDataset:
    """Packed synthetic documents with a learnable structure (a noisy
    modular-arithmetic sequence) so training loss measurably decreases."""

    def __init__(self, cfg: DataConfig, model_cfg: ModelConfig):
        self.cfg = cfg
        self.model_cfg = model_cfg
        self.vocab = model_cfg.vocab_size

    def _doc(self, rng: np.random.Generator) -> np.ndarray:
        n = max(int(rng.exponential(self.cfg.mean_doc_len)), 8)
        start = rng.integers(3, max(self.vocab // 4, 4))
        step = rng.integers(1, 7)
        toks = (start + step * np.arange(n)) % max(self.vocab - 3, 1) + 3
        noise = rng.random(n) < 0.05
        toks = np.where(noise, rng.integers(3, self.vocab, n), toks)
        return np.concatenate([[self.cfg.bos_id], toks, [self.cfg.eos_id]])

    def batch_at(self, step: int) -> dict:
        """Pure function of (seed, step): the restart-replay contract."""
        rng = np.random.default_rng((self.cfg.seed << 20) ^ step)
        b, s = self.cfg.global_batch, self.cfg.seq_len
        tokens = np.full((b, s), self.cfg.pad_id, dtype=np.int32)
        for i in range(b):
            pos = 0
            while pos < s:  # sequence packing
                doc = self._doc(rng)
                take = min(len(doc), s - pos)
                tokens[i, pos : pos + take] = doc[:take]
                pos += take
        labels = tokens.copy()
        batch = {"tokens": tokens, "labels": labels}
        mc = self.model_cfg
        if mc.family == "vlm":
            batch["image_embeds"] = rng.standard_normal(
                (b, mc.n_image_tokens, mc.d_model), dtype=np.float32)
        if mc.family == "audio":
            enc_len = max(int(s * mc.encoder_len_ratio), 16)
            batch["audio_frames"] = rng.standard_normal(
                (b, enc_len, mc.d_model), dtype=np.float32)
        return batch


def make_batches(ds: SyntheticLMDataset, start_step: int = 0,
                 prefetch: int = 2) -> Iterator[dict]:
    """Background-thread prefetching iterator starting at ``start_step``
    (restart replays from the checkpointed step)."""
    q: queue.Queue = queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def producer():
        step = start_step
        while not stop.is_set():
            try:
                q.put(ds.batch_at(step), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    try:
        while True:
            yield q.get()
    finally:
        stop.set()
