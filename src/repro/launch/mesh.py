"""Production mesh construction.

Importing this module never touches jax device state; meshes are built
lazily by functions (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips, axes (data, model).
    Multi-pod: 2 pods x 256 = 512 chips, axes (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int | None = None, *, tp: int = 2):
    """Small mesh over whatever devices exist (tests)."""
    n = n_devices or len(jax.devices())
    tp = min(tp, n)
    dp = n // tp
    return jax.make_mesh((dp, tp), ("data", "model"))


def mesh_device_count(mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
