"""End-to-end training driver.

Wires together: config -> model -> PWS planner shardings -> data pipeline ->
fault-tolerant loop with async checkpointing.  Runs on any mesh (tests use a
small host-device mesh; the production meshes come from mesh.py).

Kernel backends resolve through the ambient ``repro.kernels.policy``
execution policy; ``--impl op=backend[,op=backend]`` installs a process
policy (op: a registered kernel name or ``*``; backend: ``auto`` | ``jnp``
| ``pallas``; a bare backend means ``*=backend``) — it replaces the old
``--attention-impl``/``--matmul-impl`` pair.  ``REPRO_IMPL`` (same grammar)
works without a flag.

CLI (CPU-scale example):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --steps 50 \
      --reduced --batch 8 --seq 256 --impl '*=pallas'
"""
from __future__ import annotations

import argparse
import logging
import time
from pathlib import Path

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import SHAPES, get_config, get_smoke_config
from repro.core import planner
from repro.core.sharding_hints import axis_rules, default_rules
from repro.data import DataConfig, SyntheticLMDataset
from repro.launch.steps import make_train_step
from repro.models import build_model
from repro.models.base import RunOptions
from repro.optim import AdamWConfig, adamw_init
from repro.runtime import FaultTolerantRunner

log = logging.getLogger("repro.train")


def build_training(cfg, mesh, opts: RunOptions, opt_cfg: AdamWConfig,
                   batch_example: dict):
    """Returns (jitted step, init_fn, shardings)."""
    model = build_model(cfg, opts)
    train_step = make_train_step(model, opt_cfg)

    aparams = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    p_sh = planner.named(planner.plan_params(aparams, mesh), mesh)
    aopt = jax.eval_shape(adamw_init, aparams)
    o_sh = {
        "step": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
        "master": planner.named(planner.plan_params(aopt["master"], mesh), mesh),
        "m": planner.named(planner.plan_params(aopt["m"], mesh), mesh),
        "v": planner.named(planner.plan_params(aopt["v"], mesh), mesh),
    }
    abatch = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch_example)
    b_sh = planner.named(planner.plan_batch(abatch, mesh), mesh)

    jitted = jax.jit(train_step, in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh, None), donate_argnums=(0, 1))

    def init_state(rng):
        params = jax.jit(model.init, out_shardings=p_sh)(rng)
        opt = jax.jit(adamw_init, out_shardings=o_sh)(params)
        return params, opt

    return jitted, init_state, (p_sh, o_sh, b_sh)


def train(cfg, *, mesh, steps: int, data_cfg: DataConfig,
          opts: RunOptions = RunOptions(), opt_cfg: AdamWConfig = AdamWConfig(),
          ckpt_dir: str | None = None, save_every: int = 0,
          log_every: int = 10) -> dict:
    from repro.kernels import autotune as kernel_autotune

    # replay persisted measured tile plans for this device before the first
    # trace (no-op on a cold cache); RunOptions.autotune / REPRO_AUTOTUNE
    # select off/replay/search
    kernel_autotune.startup(opts.autotune)
    from repro.kernels import policy as kernel_policy
    prov = kernel_autotune.provenance()
    log.info("policy %s | autotune table %s (%d tuned plan(s), %s)",
             kernel_policy.current().describe(), prov["table"],
             prov["tuned_plans"],
             "present" if prov["table_exists"] else "absent")

    ds = SyntheticLMDataset(data_cfg, cfg)
    example = ds.batch_at(0)

    with mesh, axis_rules(default_rules(mesh), mesh):
        jitted, init_state, (p_sh, o_sh, _) = build_training(
            cfg, mesh, opts, opt_cfg, example)
        params, opt_state = init_state(jax.random.key(data_cfg.seed))

        runner = None
        start = 0
        if ckpt_dir:
            mgr = CheckpointManager(ckpt_dir)
            runner = FaultTolerantRunner(mgr, save_every=save_every or steps,
                                         mesh_shape=dict(mesh.shape))
            state, start = runner.restore_or(
                {"params": params, "opt_state": opt_state},
                {"params": p_sh, "opt_state": o_sh})
            params, opt_state = state["params"], state["opt_state"]

        losses = []
        t0 = time.time()
        for step in range(start, steps):
            batch = ds.batch_at(step)
            if runner is not None:
                def do_step():
                    return jitted(params, opt_state, batch)
                params, opt_state, metrics = runner.run_step(step, do_step)
            else:
                params, opt_state, metrics = jitted(params, opt_state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            if log_every and step % log_every == 0:
                log.info("step %d loss %.4f (%.2fs)", step, loss, time.time() - t0)
        if runner is not None:
            runner.ckpt.save_async(steps - 1, {"params": params, "opt_state": opt_state},
                                   dict(mesh.shape))
            runner.ckpt.wait()
        return {"losses": losses, "params": params, "opt_state": opt_state,
                "wall_s": time.time() - t0}


def main():
    logging.basicConfig(level=logging.INFO, format="%(message)s")
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true", help="smoke-size config")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=0)
    ap.add_argument("--impl", default="",
                    help="execution-policy impl map, op=backend[,op=backend] "
                         "('*' wildcard; bare backend == '*=backend') — "
                         "replaces --attention-impl/--matmul-impl; see the "
                         "module docstring for the grammar")
    args = ap.parse_args()

    if args.impl:
        from repro.kernels import policy
        impl, variants = policy.parse_impl_spec(args.impl)
        policy.install(policy.ambient().with_(impl=impl, variants=variants))

    cfg = get_smoke_config(args.arch) if args.reduced else get_config(args.arch)
    n = len(jax.devices())
    from repro.launch.mesh import make_debug_mesh
    mesh = make_debug_mesh(n, tp=min(2, n))
    out = train(cfg, mesh=mesh, steps=args.steps,
                data_cfg=DataConfig(global_batch=args.batch, seq_len=args.seq),
                opts=RunOptions(),
                ckpt_dir=args.ckpt_dir, save_every=args.save_every)
    print(f"final loss {out['losses'][-1]:.4f} (first {out['losses'][0]:.4f}) "
          f"in {out['wall_s']:.1f}s")


if __name__ == "__main__":
    main()
