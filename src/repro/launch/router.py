"""Multi-replica serving router: the scheduler above the scheduler.

``Router`` fronts N :class:`~repro.launch.engine.Engine` replicas behind
ONE request queue — the fleet-level analogue of the paper's PWS scheduler,
which allocates tasks knowing only "the available locations from which
tasks may be stolen".  The router knows each replica only through its
structured ``Engine.stats()`` surface (load, occupancy, fault counters) —
never its cache or device details — and moves requests between replicas
through the engine's host-staged row snapshots, so placement decisions are
resource-oblivious and token-exact.

**Routing arms** (``route=``):

* ``pws`` — deterministic.  Admission runs through the SAME
  ``core.pws.match_round`` the simulated machine and the engine's slot
  scheduler use, with replicas as processors: each replica contributes one
  idle "intake lane" per unit of deficit (free admissible slots plus a
  small queue-depth allowance), ranked by ``(load, rid)``; queued requests
  are the stealable tasks at priority = work remaining.  The paper's
  bounds hold one level up and are ASSERTED: at most ``n_replicas - 1``
  placements per matching round (Obs. 4.3) and non-increasing round
  priorities within a drain (§4.1).

* ``rws`` — seeded randomized two-choice per the RWS companion analysis
  ("Analysis of Randomized Work Stealing with False Sharing"): each
  placement samples two distinct replicas uniformly
  (``core.rws.two_choice``) and takes the lighter-loaded; a pick that
  lands on a replica with no intake capacity is a failed steal, retried
  next round.  The randomness perturbs *placement* only, never tokens —
  greedy decode is per-request deterministic whatever replica serves it
  (per-row cache isolation + write-before-attend, the PR-7 parity
  contract), so both arms produce request-for-request identical outputs.

**In-flight rebalancing.**  When the work-remaining skew between the most-
and least-loaded replicas crosses ``rebalance_threshold``, the router
moves one unit per round: a queued request if the donor has one, else a
decoding slot drained via ``Engine.drain_slot`` — the request re-enters
the recipient through its last host-staged snapshot and replays only the
post-snapshot greedy tail (``models.cache`` row slices carry no slot or
replica identity; ``snapshot_compatible`` gates the hand-off), so
migration is token-exact.

**Replica loss (failure-model tier (d)).**  A replica whose step escalates
``LaunchFailedError`` is marked dead: its queue and in-flight requests are
salvaged (host memory survives device loss — each rides with its last
snapshot), re-queued router-wide, and a replacement spins up through
checkpoint-streamed ``Engine.restart`` on a re-planned (possibly
shrunken) mesh via ``elastic.respawn_mesh``/``serving_restore``.  Replicas
may also join/leave live (:meth:`Router.add_replica` /
:meth:`Router.remove_replica` — elastic re-mesh): joiners stream the same
fleet checkpoint, leavers drain their requests back through the snapshot
path.

**Health + provenance.**  Each engine's PR-9 fault counters (``retries``,
``stragglers``, ``degradations``, ``degraded_iters``) fold into a
per-replica health score (``runtime.replica.health_score``); replicas
under the shed threshold stop receiving new placements (load shedding)
unless the whole fleet is shedding — progress is never sacrificed.
``policy.describe()`` + ``autotune.provenance()`` land as per-replica
provenance rows in the router telemetry.

**Fleet fault plans.**  ``fleet_faults`` extends the PR-9 grammar with
``|``-separated positional per-replica plans
(``runtime.fault_tolerance.parse_fleet_plan``): ``|decode@4=raise:99``
kills replica 1 only.  Respawned/joining replicas always get a CLEAN
injector — the plan names the fleet's initial replicas.

**Fleet clock.**  On this one-device test rig replicas time-share the
device, so the router steps them round-robin and each engine accrues wall
time on its own ``busy_s`` clock.  In production every replica is its own
accelerator and the rounds overlap, so fleet throughput is reported
against the MAKESPAN ``max(busy_s)`` (``fleet_tok_per_s``) — the
machine-checkable ratio the bench records — alongside the raw sequential
wall (``tok_per_s``), which on a single shared device cannot show the
fleet win and is kept for honesty.
"""
from __future__ import annotations

import argparse
import logging
import random
import tempfile
import time
from typing import Optional

from repro.core import pws, rws
from repro.launch.engine import Engine
from repro.launch.serve import Request
from repro.runtime.elastic import respawn_mesh
from repro.runtime.fault_tolerance import (
    FaultInjector,
    LaunchFailedError,
    parse_fleet_plan,
)
from repro.runtime.replica import Replica, spawn_replica

log = logging.getLogger("repro.router")


class Router:
    """Data-parallel request router over N engine replicas (one request
    queue, two routing arms, snapshot migration, death → checkpoint-streamed
    respawn).  See the module docstring for the full contract."""

    def __init__(self, cfg, mesh, *, n_replicas: int = 2, route: str = "pws",
                 seed: int = 0, ckpt_dir=None, fleet_faults: str = "",
                 queue_depth: int = 1,
                 rebalance_threshold: Optional[int] = None,
                 respawn: bool = True, **engine_kw):
        if route not in ("pws", "rws"):
            raise ValueError(f"unknown routing arm {route!r}: "
                             "expected 'pws' or 'rws'")
        self.cfg = cfg
        self.mesh = mesh
        self.route = route
        self.seed = int(seed)
        self.queue_depth = int(queue_depth)
        self.rebalance_threshold = rebalance_threshold
        self.respawn = respawn
        self._engine_kw = dict(engine_kw)
        self._work = Engine._work_remaining

        plans = parse_fleet_plan(fleet_faults, n_replicas)
        # replica 0 initializes fresh and seeds the fleet checkpoint; every
        # other replica (and every respawn/join) spins up checkpoint-streamed
        # through Engine.restart, so all replicas serve identical logits
        self.ckpt_dir = ckpt_dir or tempfile.mkdtemp(prefix="repro-router-")
        self.replicas: list[Replica] = [
            spawn_replica(0, cfg, mesh, None,
                          injector=FaultInjector(plans[0]), **engine_kw)]
        from repro.checkpoint import save_checkpoint
        save_checkpoint(self.ckpt_dir, 0,
                        {"params": self.replicas[0].engine.params},
                        mesh_shape=dict(mesh.shape))
        for rid in range(1, n_replicas):
            self.replicas.append(
                spawn_replica(rid, cfg, mesh, self.ckpt_dir,
                              injector=FaultInjector(plans[rid]),
                              **engine_kw))
        self._by_rid = {r.rid: r for r in self.replicas}
        self._next_rid = n_replicas
        self.begin([])

    # -- fleet state ---------------------------------------------------------
    def _live(self) -> list[Replica]:
        return [r for r in self.replicas if r.state == "live"]

    def _deficit(self, rep: Replica) -> int:
        """Intake capacity: free admissible slots plus the queue-depth
        allowance, minus requests already queued on the replica."""
        occ = rep.engine.stats()["occupancy"]
        free = min(occ["free"],
                   rep.engine.stats()["degradation"]["active_limit"])
        return max(0, free + self.queue_depth - occ["queued"])

    def _candidates(self) -> list[Replica]:
        """Live replicas eligible for new placements: shedding removes
        unhealthy ones unless the WHOLE fleet is unhealthy (the last
        candidate is never shed — progress beats shedding)."""
        live = self._live()
        ok = [r for r in live if not r.shed()]
        if self.queue and ok and len(ok) < len(live):
            self.counters["sheds"] += len(live) - len(ok)
        return ok or live

    # -- run lifecycle -------------------------------------------------------
    def begin(self, requests: list[Request] = ()):
        """Start a fleet run: one global queue, fresh per-run counters, a
        re-seeded placement rng (same seed → same placements), and a
        ``begin`` on every live replica."""
        self.queue: list[Request] = list(requests)
        uids = [r.uid for r in self.queue]
        assert len(set(uids)) == len(uids), "request uids must be unique"
        self.rng = random.Random(self.seed)
        self.placements: list[tuple[int, int]] = []
        self._snaps: dict[int, dict] = {}  # uid -> {"snap", "origin"}
        self.counters = {
            "route_rounds": 0, "failed_steals": 0, "sheds": 0,
            "queue_moves": 0, "slot_migrations": 0, "migrations": 0,
            "rebalances": 0, "replica_deaths": 0, "requeued_on_death": 0,
            "replica_restarts": 0, "joins": 0, "leaves": 0,
            "routed": {r.rid: 0 for r in self._live()},
        }
        for rep in self._live():
            rep.engine.begin([])
        self._t0 = time.time()

    def done(self) -> bool:
        return not self.queue and all(not r.engine.busy()
                                      for r in self._live())

    def step_round(self):
        """One fleet round: route, step every live busy replica once
        (catching tier-(d) escalations), rebalance, refresh health."""
        if not self._live():
            raise RuntimeError("no live replicas and respawn is off")
        self._route()
        for rep in list(self.replicas):
            if rep.state != "live" or not rep.engine.busy():
                continue
            try:
                rep.engine.step()
            except LaunchFailedError as e:
                self._on_death(rep, e)
        self._rebalance()
        for rep in self._live():
            rep.refresh_health()

    def run(self, requests: list[Request]) -> dict:
        """Serve ``requests`` across the fleet to completion; greedy decode.
        Per-request tokens land in ``request.out``, request-for-request
        identical to a clean single-replica run whatever the arm, the
        placement, the migrations, or the deaths along the way."""
        self.begin(requests)
        while not self.done():
            self.step_round()
        return self.finish(requests)

    def finish(self, requests: list[Request]) -> dict:
        """Seal every live engine's counters and assemble the fleet view:
        router counters, the placement log, per-replica provenance rows,
        and both throughput clocks (see "Fleet clock" in the module
        docstring)."""
        for rep in self.replicas:
            if rep.state == "live":
                rep.engine.finish()
            rep.refresh_health()
        dt = time.time() - self._t0
        fleet = max((r.engine.busy_s for r in self.replicas), default=dt)
        n_tokens = sum(len(r.out) for r in requests)
        return {
            "wall_s": dt,
            "fleet_busy_s": fleet,
            "tokens": n_tokens,
            "tok_per_s": n_tokens / max(dt, 1e-9),
            "fleet_tok_per_s": n_tokens / max(fleet, 1e-9),
            "counters": {k: (dict(v) if isinstance(v, dict) else v)
                         for k, v in self.counters.items()},
            "placements": list(self.placements),
            "replicas": [{**rep.provenance(), "busy_s": rep.engine.busy_s}
                         for rep in self.replicas],
        }

    # -- routing arms --------------------------------------------------------
    def _place(self, req: Request, rid: int):
        entry = self._snaps.pop(req.uid, None)
        snap = entry["snap"] if entry else None
        if snap is not None and entry["origin"] != rid:
            # a snapshot taken on one replica resuming on another: the
            # cross-replica snapshot-resume migration the acceptance names
            self.counters["migrations"] += 1
        self._by_rid[rid].engine.adopt(req, snap)
        self.counters["routed"][rid] = \
            self.counters["routed"].get(rid, 0) + 1
        self.placements.append((req.uid, rid))

    def _route(self):
        if not self.queue:
            return
        if self.route == "pws":
            self._route_pws()
        else:
            self._route_rws()

    def _route_pws(self):
        """Deterministic arm: ``match_round`` over replicas-as-processors.
        Idle intake lanes rank by ``(load, rid, lane)`` — lighter replicas
        steal first — and the per-round placement bound + non-increasing
        priorities are asserted exactly as in the engine's slot
        scheduler."""
        cands = self._candidates()
        bound = max(len(cands) - 1, 1)
        last_best: Optional[int] = None
        while self.queue:
            idle = []
            for rep in cands:
                load = rep.engine.work_remaining_total()
                for lane in range(self._deficit(rep)):
                    idle.append(((load, rep.rid, lane), rep.rid))
            if not idle:
                return
            heads = [(i, self._work(r)) for i, r in enumerate(self.queue)]
            best, pairs = pws.match_round(idle, heads)
            if best is None:
                return
            # Obs. 4.3 one level up: at most n_replicas - 1 placements of
            # the round's priority
            pairs = pairs[:bound]
            assert len(pairs) <= bound, \
                "router bounded-steals-per-round invariant violated"
            assert last_best is None or best <= last_best, \
                "router round priorities must be non-increasing"
            last_best = best
            self.counters["route_rounds"] += 1
            # pop in descending queue order so earlier indices stay valid
            for (_, rid), qidx in sorted(pairs, key=lambda p: -p[1]):
                self._place(self.queue.pop(qidx), rid)

    def _route_rws(self):
        """Randomized arm: head-of-queue (largest work remaining — the RWS
        steal-the-top discipline) placed by seeded two-choice over the
        candidate loads; a pick without intake capacity is a failed steal,
        retried next round (the analysis' unit-delay retry)."""
        cands = self._candidates()
        while self.queue:
            if not any(self._deficit(r) > 0 for r in cands):
                return
            loads = {r.rid: r.engine.work_remaining_total() for r in cands}
            qidx = max(range(len(self.queue)),
                       key=lambda i: (self._work(self.queue[i]), -i))
            rid = rws.two_choice(self.rng, sorted(loads), loads)
            self.counters["route_rounds"] += 1
            if self._deficit(self._by_rid[rid]) <= 0:
                self.counters["failed_steals"] += 1
                return
            self._place(self.queue.pop(qidx), rid)

    # -- rebalancing ---------------------------------------------------------
    def _rebalance(self):
        """Move one unit of work per fleet round from the most- to the
        least-loaded replica while the skew exceeds the threshold."""
        if self.rebalance_threshold is None:
            return
        live = self._live()
        if len(live) < 2:
            return
        loads = {r.rid: r.engine.work_remaining_total() for r in live}
        donor = max(live, key=lambda r: (loads[r.rid], -r.rid))
        rec = min(live, key=lambda r: (loads[r.rid], r.rid))
        if loads[donor.rid] - loads[rec.rid] <= self.rebalance_threshold:
            return
        if self._move_one(donor, rec):
            self.counters["rebalances"] += 1

    def _move_one(self, donor: Replica, rec: Replica) -> bool:
        """One rebalance transfer: a queued request when the donor has one
        (free — no cache state moves), else the donor's heaviest decoding
        slot drained with its snapshot (token-exact tail replay on the
        recipient).  Returns False when nothing movable."""
        eng = donor.engine
        if eng.queue:
            qidx = max(range(len(eng.queue)),
                       key=lambda i: (self._work(eng.queue[i]), -i))
            req, snap = eng.withdraw_queued(qidx)
            kind = "queue_moves"
        else:
            if self._deficit(rec) <= 0:
                return False
            decode = [(self._work(s.req, s.context), -i, i)
                      for i, s in enumerate(eng.slots)
                      if s.state == "decode"]
            if not decode:
                return False
            req, snap = eng.drain_slot(max(decode)[2])
            kind = "slot_migrations"
        if snap is not None:
            self._snaps[req.uid] = {"snap": snap, "origin": donor.rid}
        self.counters[kind] += 1
        self._place(req, rec.rid)
        return True

    # -- replica loss + elastic re-mesh --------------------------------------
    def _on_death(self, rep: Replica, err: LaunchFailedError):
        """Failure-model tier (d): salvage (host snapshots survive device
        loss), re-queue router-wide, respawn checkpoint-streamed."""
        rep.state = "dead"
        rep.refresh_health()
        self.counters["replica_deaths"] += 1
        salvaged = rep.engine.salvage()
        for req, snap in salvaged:
            if snap is not None:
                self._snaps[req.uid] = {"snap": snap, "origin": rep.rid}
            self.queue.append(req)
        self.counters["requeued_on_death"] += len(salvaged)
        log.warning("replica %d died (%s): %d request(s) re-queued fleet-wide",
                    rep.rid, err, len(salvaged))
        if self.respawn:
            self.add_replica(_counter="replica_restarts")

    def add_replica(self, mesh=None, *, _counter: str = "joins") -> Replica:
        """Elastic join (and the respawn path): spin a new replica from the
        fleet checkpoint through ``Engine.restart`` on ``mesh`` — default
        ``elastic.respawn_mesh`` of the fleet mesh (same device count, or
        shrunken when the dead replica took hosts with it).  Joiners and
        respawns always get a clean injector."""
        rid = self._next_rid
        self._next_rid += 1
        rep = spawn_replica(rid, self.cfg, mesh or respawn_mesh(self.mesh),
                            self.ckpt_dir, injector=FaultInjector(""),
                            **self._engine_kw)
        rep.engine.begin([])
        self.replicas.append(rep)
        self._by_rid[rid] = rep
        self.counters[_counter] += 1
        self.counters["routed"].setdefault(rid, 0)
        return rep

    def remove_replica(self, rid: int):
        """Elastic leave: drain everything the replica holds back into the
        router queue (in-flight decodes ride their snapshots and resume
        elsewhere token-exactly) and retire it from the fleet."""
        rep = self._by_rid[rid]
        if rep.state != "live":
            raise ValueError(f"replica {rid} is {rep.state}, not live")
        if len(self._live()) < 2:
            raise ValueError("cannot remove the last live replica")
        for req, snap in rep.engine.salvage():
            if snap is not None:
                self._snaps[req.uid] = {"snap": snap, "origin": rid}
            self.queue.append(req)
        rep.state = "left"
        self.counters["leaves"] += 1


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--route", default="pws", choices=("pws", "rws"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--chunk", type=int, default=16)
    ap.add_argument("--snapshot-every", type=int, default=4)
    ap.add_argument("--evict-policy", default="largest",
                    choices=("largest", "coldest"))
    ap.add_argument("--queue-depth", type=int, default=1)
    ap.add_argument("--rebalance-threshold", type=int, default=0,
                    help="work-remaining skew that triggers a migration "
                         "(0 = rebalancing off)")
    ap.add_argument("--inject", default="",
                    help="fleet fault plan: '|'-separated per-replica PR-9 "
                         "plans, e.g. '|decode@4=raise:99' kills replica 1 "
                         "(default: the REPRO_FAULTS env plan)")
    ap.add_argument("--check-single", action="store_true",
                    help="re-serve the workload on a clean 1-replica engine "
                         "and assert request-for-request token identity")
    ap.add_argument("--min-restarts", type=int, default=0,
                    help="assert at least N checkpoint-streamed replica "
                         "respawns happened (CI fault arm)")
    ap.add_argument("--impl", default="",
                    help="execution-policy impl map (see serve.py docstring)")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO, format="%(message)s")

    if args.impl:
        from repro.kernels import policy
        impl, variants = policy.parse_impl_spec(args.impl)
        policy.install(policy.ambient().with_(impl=impl, variants=variants))

    import os

    import jax
    import numpy as np

    from repro.configs import get_config, get_smoke_config
    from repro.launch.mesh import make_debug_mesh
    from repro.models.base import RunOptions

    cfg = get_smoke_config(args.arch) if args.reduced else get_config(args.arch)
    mesh = make_debug_mesh(tp=min(2, len(jax.devices())))
    plan = args.inject or os.environ.get("REPRO_FAULTS", "")
    engine_kw = dict(max_batch=args.slots, max_len=128, chunk=args.chunk,
                     snapshot_every=args.snapshot_every,
                     evict_policy=args.evict_policy, opts=RunOptions())
    router = Router(cfg, mesh, n_replicas=args.replicas, route=args.route,
                    seed=args.seed, fleet_faults=plan,
                    queue_depth=args.queue_depth,
                    rebalance_threshold=args.rebalance_threshold or None,
                    **engine_kw)

    rng = np.random.default_rng(0)
    spec = [(rng.integers(3, cfg.vocab_size,
                          int(rng.integers(4, 24))).astype(np.int32),
             int(rng.integers(2, args.max_new + 1)))
            for _ in range(args.requests)]
    reqs = [Request(i, p, max_new=mn) for i, (p, mn) in enumerate(spec)]
    out = router.run(reqs)
    print(f"served {out['tokens']} tokens across "
          f"{len(out['replicas'])} replica row(s): "
          f"fleet {out['fleet_tok_per_s']:.1f} tok/s (makespan "
          f"{out['fleet_busy_s']:.2f}s), sequential {out['tok_per_s']:.1f} "
          f"tok/s ({out['wall_s']:.2f}s)")
    print(f"router counters: {out['counters']}")
    for row in out["replicas"]:
        print(f"replica {row['rid']}: state={row['state']} "
              f"from={row['spawned_from']} health={row['health']:.2f} "
              f"mesh={row['mesh']} policy={row['policy']}")
    if args.min_restarts:
        assert out["counters"]["replica_restarts"] >= args.min_restarts, \
            (f"expected >= {args.min_restarts} replica restart(s), got "
             f"{out['counters']['replica_restarts']}")
        assert out["counters"]["migrations"] >= 1, \
            "expected at least one cross-replica snapshot-resume migration"
    if args.check_single:
        single = Engine(cfg, mesh, injector=FaultInjector(""), **engine_kw)
        single.params = router.replicas[0].engine.params
        alone = [Request(i, p, max_new=mn) for i, (p, mn) in enumerate(spec)]
        single.run(alone)
        assert [r.out for r in alone] == [r.out for r in reqs], \
            "router tokens diverge from the clean single-replica run"
        print("single-replica token parity: OK")


if __name__ == "__main__":
    main()
