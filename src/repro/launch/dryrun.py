import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell with
ShapeDtypeStruct inputs (no allocation), record memory/cost/collective
analysis.  The two lines above MUST stay first: jax locks the device count on
first init.

Usage:
  python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all            # every cell, subprocess each
  python -m repro.launch.dryrun --all --mesh multi
"""
import argparse
import gzip
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

REPO = Path(__file__).resolve().parents[3]
OUT_DIR = REPO / "experiments" / "dryrun"
HLO_DIR = OUT_DIR / "hlo"

# v5e-like hardware model (per chip)
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s/link


def cell_id(arch: str, shape: str, mesh: str, tag: str = "") -> str:
    t = f"_{tag}" if tag else ""
    return f"{arch}_{shape}_{mesh}{t}"


# hand-tuned microbatch counts for the heavy cells (planner table — measured
# to fit 16 GB/device HBM; see EXPERIMENTS.md §Dry-run)
_MICROBATCH_TABLE = {
    ("llama-3.2-vision-90b", "train_4k", "single"): 16,
    ("llama-3.2-vision-90b", "train_4k", "multi"): 8,  # = global_batch/dp
    ("qwen3-32b", "train_4k", "single"): 2,
    ("qwen3-32b", "train_4k", "multi"): 2,
}


def planner_defaults(cfg, shape, mesh) -> dict:
    """Resource-aware RunOptions chosen by the planner (models never see the
    mesh; the scheduler does — the paper's division of labor).

    * microbatches: tuned table for the heavy cells; fallback formula keeps
      the per-device residual activation stack under ~2 GB.
    * moe_groups: one dispatch group per data shard.
    """
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            dp *= mesh.shape[a]
    tp = mesh.shape.get("model", 1)
    mesh_kind = "multi" if "pod" in mesh.shape else "single"
    out: dict = {}
    if cfg.n_experts:
        out["moe_groups"] = dp
    if shape.kind == "train":
        key = (cfg.name, shape.name, mesh_kind)
        if key in _MICROBATCH_TABLE:
            out["microbatches"] = _MICROBATCH_TABLE[key]
            return out
        layers = cfg.n_layers + (cfg.encoder_layers or 0)
        per_dev_tokens = max(shape.global_batch // dp, 1) * shape.seq_len / tp
        est = layers * per_dev_tokens * cfg.d_model * 2  # bf16 residual stack
        micro = 1
        while est / micro > 6e9 and micro < max(shape.global_batch // dp, 1):
            micro *= 2
        if micro > 1:
            out["microbatches"] = micro
    return out


def run_cell(arch: str, shape_name: str, mesh_kind: str, *, opts_kw: dict | None = None,
             save_hlo: bool = True, tag: str = "") -> dict:
    import jax

    from repro.configs import SHAPES, get_config
    from repro.core import planner
    from repro.core.sharding_hints import axis_rules, default_rules
    from repro.launch import hlo_analysis
    from repro.launch.mesh import make_production_mesh, mesh_device_count
    from repro.launch.steps import build_step_bundle
    from repro.models.base import RunOptions

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rec: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "tag": tag,
        "kind": shape.kind, "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "status": "ok",
    }

    if shape_name == "long_500k" and not cfg.supports_long_context:
        rec["status"] = "SKIP(full-attention)"
        return rec

    opts_kw = dict(opts_kw or {})
    rules_override = opts_kw.pop("axis_rules", {})
    param_mode = opts_kw.pop("param_sharding", "fsdp")
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    opts_kw = {**planner_defaults(cfg, shape, mesh), **opts_kw}
    opts = RunOptions(**opts_kw)
    rec["opts"] = {**opts_kw, "param_sharding": param_mode}
    n_dev = mesh_device_count(mesh)
    rec["n_devices"] = n_dev

    bundle = build_step_bundle(cfg, shape, opts)

    in_shardings = []
    for arg, kind in zip(bundle.args, bundle.kinds):
        if kind == "params":
            in_shardings.append(planner.named(
                planner.plan_params(arg, mesh, mode=param_mode), mesh))
        elif kind == "opt":
            spec = {
                "step": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
                "master": planner.named(planner.plan_params(arg["master"], mesh), mesh),
                "m": planner.named(planner.plan_params(arg["m"], mesh), mesh),
                "v": planner.named(planner.plan_params(arg["v"], mesh), mesh),
            }
            in_shardings.append(spec)
        elif kind == "batch":
            in_shardings.append(planner.named(planner.plan_batch(arg, mesh), mesh))
        elif kind == "cache":
            in_shardings.append(planner.named(planner.plan_cache(arg, mesh), mesh))
        else:  # scalar
            in_shardings.append(jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()))

    # outputs: params/opt/cache keep their input layout (donated); rest auto
    if bundle.name == "train_step":
        out_shardings = (in_shardings[0], in_shardings[1], None)
        donate = (0, 1)
    elif bundle.name == "prefill_step":
        cache_spec = planner.named(planner.plan_cache(
            jax.eval_shape(bundle.fn, *bundle.args)[1], mesh), mesh)
        out_shardings = (None, cache_spec)
        donate = ()
    else:  # serve_step
        out_shardings = (None, in_shardings[3])
        donate = (3,)

    rules = default_rules(mesh)
    rules.update(rules_override)
    t0 = time.time()
    with mesh, axis_rules(rules, mesh):
        jitted = jax.jit(bundle.fn, in_shardings=tuple(in_shardings),
                         out_shardings=out_shardings, donate_argnums=donate)
        lowered = jitted.lower(*bundle.args)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

    ma = compiled.memory_analysis()
    if ma is not None:
        rec["memory"] = {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_bytes_est": ma.argument_size_in_bytes + ma.output_size_in_bytes
            + ma.temp_size_in_bytes - ma.alias_size_in_bytes,
        }
    ca = compiled.cost_analysis() or {}
    rec["xla_cost"] = {"flops": ca.get("flops"), "bytes_accessed": ca.get("bytes accessed")}

    txt = compiled.as_text()
    stats = hlo_analysis.analyze(txt, n_devices_default=n_dev)
    rec["hlo"] = stats.as_dict()

    # roofline terms (per device quantities vs per-chip peaks)
    rec["roofline"] = {
        "compute_s": stats.flops / PEAK_FLOPS,
        "memory_s": stats.hbm_bytes / HBM_BW,
        "collective_s": stats.collective_bytes / ICI_BW,
    }
    dom = max(rec["roofline"], key=rec["roofline"].get)
    rec["roofline"]["dominant"] = dom

    if save_hlo:
        HLO_DIR.mkdir(parents=True, exist_ok=True)
        with gzip.open(HLO_DIR / f"{cell_id(arch, shape_name, mesh_kind, tag)}.hlo.gz",
                       "wt") as f:
            f.write(txt)
    return rec


def all_cells(mesh_kinds: list[str]) -> list[tuple[str, str, str]]:
    from repro.configs import SHAPES, list_archs

    cells = []
    for mesh_kind in mesh_kinds:
        for arch in list_archs():
            for shape in SHAPES:
                cells.append((arch, shape, mesh_kind))
    return cells


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--tag", default="", help="variant tag for perf experiments")
    ap.add_argument("--opts", default="", help="JSON RunOptions overrides")
    ap.add_argument("--impl", default="",
                    help="execution-policy impl map, op=backend[,op=backend] "
                         "('*' wildcard) — exported as REPRO_IMPL so every "
                         "lowered cell (including --all subprocesses) "
                         "assembles the same ambient policy")
    ap.add_argument("--timeout", type=int, default=1800)
    ap.add_argument("--no-hlo", action="store_true")
    args = ap.parse_args()

    if args.impl:
        from repro.kernels import policy

        policy.parse_impl_spec(args.impl)  # validate (impl + knobs) pre-fan-out
        os.environ["REPRO_IMPL"] = args.impl

    OUT_DIR.mkdir(parents=True, exist_ok=True)

    if args.all:
        mesh_kinds = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        cells = all_cells(mesh_kinds)
        failures = 0
        for arch, shape, mesh_kind in cells:
            out_file = OUT_DIR / f"{cell_id(arch, shape, mesh_kind, args.tag)}.json"
            if out_file.exists():
                print(f"[skip-cached] {out_file.name}")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
                   "--shape", shape, "--mesh", mesh_kind]
            if args.tag:
                cmd += ["--tag", args.tag]
            if args.opts:
                cmd += ["--opts", args.opts]
            if args.no_hlo:
                cmd += ["--no-hlo"]
            t0 = time.time()
            try:
                r = subprocess.run(cmd, capture_output=True, text=True,
                                   timeout=args.timeout,
                                   env={**os.environ, "PYTHONPATH": str(REPO / "src")})
                ok = r.returncode == 0
            except subprocess.TimeoutExpired:
                ok = False
                r = None
            if not ok:
                failures += 1
                err = (r.stderr[-2000:] if r else "TIMEOUT")
                rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                       "tag": args.tag, "status": f"FAIL: {err}"}
                out_file.write_text(json.dumps(rec, indent=1))
                print(f"[FAIL {time.time()-t0:6.0f}s] {arch} {shape} {mesh_kind}")
            else:
                print(f"[ok   {time.time()-t0:6.0f}s] {arch} {shape} {mesh_kind}")
        print(f"done, {failures} failures / {len(cells)} cells")
        return 1 if failures else 0

    opts_kw = json.loads(args.opts) if args.opts else {}
    rec = run_cell(args.arch, args.shape, args.mesh, opts_kw=opts_kw,
                   save_hlo=not args.no_hlo, tag=args.tag)
    out_file = OUT_DIR / f"{cell_id(args.arch, args.shape, args.mesh, args.tag)}.json"
    out_file.write_text(json.dumps(rec, indent=1))
    print(json.dumps(rec, indent=1))
    return 0 if rec["status"].startswith(("ok", "SKIP")) else 1


if __name__ == "__main__":
    sys.exit(main())
