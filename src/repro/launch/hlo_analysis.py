"""Static analysis of optimized (post-SPMD) HLO text.

Why this exists: ``compiled.cost_analysis()`` visits ``while`` bodies ONCE,
but our layer stacks are ``lax.scan`` loops — a 64-layer model's flops would
be undercounted 64x.  This module parses the optimized HLO, propagates
execution-count multipliers through the call graph (while trip counts from
``backend_config={"known_trip_count":...}``, fusion/call edges), and derives:

  * ``flops``             — MXU flops (dot/convolution), trip-count weighted
  * ``hbm_bytes``         — estimated HBM traffic: for every materializing
                            instruction, operand bytes + result bytes
                            (dynamic-update-slice counted in-place)
  * ``collective_bytes``  — per-collective wire bytes per device, using ring
                            cost models (all-reduce 2S(N-1)/N, all-gather
                            S(N-1)/N, reduce-scatter S_in(N-1)/N, all-to-all
                            S(N-1)/N, collective-permute S)
  * per-collective-op breakdown for the §Dry-run log.

All quantities are PER DEVICE (the HLO module is the per-device SPMD
program).  This is a *static* traffic model: layout-change ops (transpose /
broadcast / concatenate) are counted as materializing because they do
materialize on the TPU target, even though the CPU backend may bitcast some
of them.
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field

DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1, "f8e5m2fnuz": 1,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute",
)

MATERIALIZING = {
    "fusion", "dot", "convolution", "copy", "gather", "scatter", "reduce",
    "reduce-window", "sort", "transpose", "broadcast", "iota", "concatenate",
    "slice", "dynamic-slice", "pad", "reverse", "select-and-scatter",
    "rng", "rng-bit-generator", "custom-call",
} | set(COLLECTIVE_OPS)


def shape_bytes(type_str: str) -> float:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclass
class Instruction:
    name: str
    result_type: str
    op: str
    operands: list[str]
    line: str


@dataclass
class Computation:
    name: str
    instructions: list[Instruction] = field(default_factory=list)
    types: dict[str, str] = field(default_factory=dict)  # name -> result type
    root: Instruction | None = None


_INSTR_RE = re.compile(
    r"^\s*(ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^=]*?\)|[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?))\s*"
    r"([\w\-]+)\((.*?)\)(.*)$"
)
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\((.*?)\)\s*->")
_PARAM_RE = re.compile(r"([\w\.\-]+):\s*((?:\([^()]*\)|[a-z][a-z0-9]*\[[0-9,]*\]))")
_TRIP_RE = re.compile(r"known_trip_count[^0-9]*?(\d+)")
_REPLICA_GROUPS_RE = re.compile(r"replica_groups=(\{\{.*?\}\}|\[[0-9,]+\]<=\[[0-9,]+\])")


def parse_hlo(text: str) -> tuple[dict[str, Computation], str | None]:
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" "):
            m = _COMP_HEADER_RE.match(line)
            if m and "{" in line:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                if line.startswith("ENTRY"):
                    entry = cur.name
                for pname, ptype in _PARAM_RE.findall(m.group(2)):
                    cur.types[pname] = ptype
            continue
        if cur is None:
            continue
        if "/*" in line:
            line = re.sub(r"/\*.*?\*/", "", line)
        m = _INSTR_RE.match(line)
        if not m:
            continue
        is_root, name, rtype, op, operands, _attrs = m.groups()
        # operands appear as "f32[4,64]{1,0} %name" in optimized dumps: keep
        # only the trailing token, else type lookups (dot contraction dims,
        # HBM operand bytes) silently miss and undercount
        ops = [o.strip().split()[-1].lstrip("%") for o in _split_operands(operands)]
        instr = Instruction(name, rtype, op, ops, line)
        cur.instructions.append(instr)
        cur.types[name] = rtype
        if is_root:
            cur.root = instr
    for comp in comps.values():
        if comp.root is None and comp.instructions:
            comp.root = comp.instructions[-1]
    return comps, entry


def _split_operands(s: str) -> list[str]:
    out, depth, cur = [], 0, []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    return [o for o in (x.strip() for x in out) if o]


def _callees(instr: Instruction, unknown_counter: list[int]) -> list[tuple[str, float]]:
    """(callee computation, execution weight) edges for one instruction."""
    line = instr.line
    if instr.op == "while":
        tm = _TRIP_RE.search(line)
        trips = int(tm.group(1)) if tm else 1
        if not tm:
            unknown_counter[0] += 1
        bm = re.search(r"body=%?([\w\.\-]+)", line)
        return [(bm.group(1), float(trips))] if bm else []
    if instr.op in ("fusion", "call", "async-start"):
        cm = re.search(r"calls=%?([\w\.\-]+)", line)
        return [(cm.group(1), 1.0)] if cm else []
    if instr.op == "conditional":
        cm = re.search(r"branch_computations=\{([^}]*)\}", line)
        if cm:
            return [(b.strip().lstrip("%"), 1.0) for b in cm.group(1).split(",")]
    return []


def _group_size(line: str, default: int) -> int:
    m = _REPLICA_GROUPS_RE.search(line)
    if not m:
        return default
    g = m.group(1)
    if g.startswith("{{"):
        first = g[2:].split("}", 1)[0]
        return max(len([x for x in first.split(",") if x.strip() != ""]), 1)
    lhs = g.split("<=")[0].strip("[]")
    dims = [int(x) for x in lhs.split(",")]
    return dims[-1] if dims else default


def _dot_flops(instr: Instruction, comp: Computation) -> float:
    dims = shape_dims(instr.result_type)
    out_elems = math.prod(dims) if dims else 1
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.line)
    contraction = 1
    if m and instr.operands:
        lhs_dims = shape_dims(comp.types.get(instr.operands[0], ""))
        for i in (int(x) for x in m.group(1).split(",") if x != ""):
            if i < len(lhs_dims):
                contraction *= lhs_dims[i]
    return 2.0 * out_elems * contraction


@dataclass
class HloStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    collectives: dict[str, dict] = field(default_factory=dict)
    n_while: int = 0
    unknown_trip_counts: int = 0

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "collectives": self.collectives,
            "n_while": self.n_while,
            "unknown_trip_counts": self.unknown_trip_counts,
        }


def analyze(text: str, n_devices_default: int = 1) -> HloStats:
    comps, entry = parse_hlo(text)
    stats = HloStats()
    if entry is None:
        return stats

    unknown = [0]
    edges: dict[str, list[tuple[str, float]]] = {}
    for cname, comp in comps.items():
        es: list[tuple[str, float]] = []
        for instr in comp.instructions:
            if instr.op == "while":
                stats.n_while += 1
            es.extend(_callees(instr, unknown))
        edges[cname] = es
    stats.unknown_trip_counts = unknown[0]

    # topological order (DFS postorder reversed), call graph is a DAG
    topo: list[str] = []
    state: dict[str, int] = {}

    def dfs(c: str):
        stack = [(c, iter(edges.get(c, ())))]
        state[c] = 1
        while stack:
            node, it = stack[-1]
            advanced = False
            for callee, _w in it:
                if state.get(callee, 0) == 0 and callee in comps:
                    state[callee] = 1
                    stack.append((callee, iter(edges.get(callee, ()))))
                    advanced = True
                    break
            if not advanced:
                topo.append(node)
                state[node] = 2
                stack.pop()

    dfs(entry)
    topo.reverse()  # callers before callees

    mult: dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    for cname in topo:
        m = mult[cname]
        if m == 0.0:
            continue
        for callee, w in edges.get(cname, ()):
            if callee in comps:
                mult[callee] += m * w

    # which computations root in a dynamic-update-slice (in-place fusions)
    root_is_dus = {
        cname: (comp.root is not None and comp.root.op == "dynamic-update-slice")
        for cname, comp in comps.items()
    }

    coll_acc: dict[str, dict] = defaultdict(
        lambda: {"count": 0.0, "wire_bytes": 0.0, "payload_bytes": 0.0}
    )
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        for instr in comp.instructions:
            op = instr.op
            base = op[:-6] if op.endswith("-start") else op
            if op in ("dot", "convolution"):
                stats.flops += m * _dot_flops(instr, comp)
            if base in COLLECTIVE_OPS and not op.endswith("-done"):
                payload = shape_bytes(instr.result_type)
                n = _group_size(instr.line, n_devices_default)
                frac = (n - 1) / n if n > 1 else 0.0
                if base == "all-reduce":
                    wire = 2.0 * payload * frac
                elif base == "all-gather":
                    wire = payload * frac
                elif base == "reduce-scatter":
                    wire = payload * max(n - 1, 0)
                elif base == "all-to-all":
                    wire = payload * frac
                else:  # collective-permute
                    wire = payload
                stats.collective_bytes += m * wire
                acc = coll_acc[base]
                acc["count"] += m
                acc["wire_bytes"] += m * wire
                acc["payload_bytes"] += m * payload
            if base in MATERIALIZING and not op.endswith("-done"):
                stats.hbm_bytes += m * _instr_hbm_bytes(instr, comp, comps, root_is_dus)
            elif op == "dynamic-update-slice":
                stats.hbm_bytes += m * _instr_hbm_bytes(instr, comp, comps, root_is_dus)

    stats.collectives = {k: v for k, v in sorted(coll_acc.items())}
    return stats


def _instr_hbm_bytes(instr, comp, comps, root_is_dus) -> float:
    """HBM traffic for one materializing instruction."""
    operand_bytes = [shape_bytes(comp.types.get(o, "")) for o in instr.operands]
    rbytes = shape_bytes(instr.result_type)

    inplace = instr.op == "dynamic-update-slice"
    if instr.op == "fusion":
        cm = re.search(r"calls=%?([\w\.\-]+)", instr.line)
        if cm and root_is_dus.get(cm.group(1), False):
            inplace = True
    if inplace:
        # read all operands except the aliased (largest) buffer; write = the
        # updated region, approximated by the largest non-aliased operand.
        big = max(operand_bytes) if operand_bytes else 0.0
        reads = sum(operand_bytes) - big
        update = max([b for b in operand_bytes if b != big] or [rbytes * 0.0])
        return reads + update
    return rbytes + sum(operand_bytes)


def count_collective_ops(text: str) -> dict[str, int]:
    """Raw (unweighted) op counts, for quick sanity logging."""
    from collections import Counter

    return dict(Counter(re.findall(r"\b(" + "|".join(COLLECTIVE_OPS) + r")", text)))
