"""Continuous-batching serving engine: PWS-disciplined slot scheduling over
per-row KV decode.

The lockstep server (``repro.launch.serve``) decodes a fixed wave of
requests at one shared position: rows that finish early burn decode steps
until the slowest request in the wave ends, and a new wave cannot start
until the old one drains.  This engine removes both stalls:

* **Per-row KV lengths.**  Every decode step runs the whole ``max_batch``
  at once, but each slot carries its OWN position — the flash-decode
  kernel's per-row ``q_offset``/``kv_len`` SMEM vectors (see
  ``repro.kernels.flash_attention``) mask each lane's cache prefix
  independently, so caches at different depths coexist in one launch, and
  the traced vectors keep the no-recompile property across steps of
  varying per-row lengths.

* **Slot reuse.**  A request that hits EOS / ``max_new`` / the cache
  capacity releases its slot immediately (an *eviction*); the next queued
  request is admitted into it without waiting for the rest of the batch.

* **Batched chunked prefill.**  Prompts stream into the cache in
  fixed-size chunks (``prefill_chunk`` on the model), and every prefilling
  slot advances each iteration through at most TWO padded full-batch
  launches — one for first chunks (modality frontends / int8 scale
  calibration run there), one for continuations — with per-row ``(b,)``
  offsets and valid-token ``lens`` (0 parks a row).  Chunks interleave
  with decode steps so a long prompt never stalls in-flight rows.

* **PWS slot scheduling.**  Admission is the paper's §4.7 priority-matching
  discipline, run through the same ``core.pws.match_round`` the simulated
  machine's scheduler uses: queued requests are stealable tasks, idle slots
  are thieves, priority = work remaining (prompt tokens still to prefill +
  tokens still to generate — the size-based order).  Rounds are
  deterministic, match at most ``p - 1`` requests of the round's priority
  (Obs. 4.3, asserted), and round priorities are non-increasing within a
  drain (asserted).  The scheduler's match/steal/eviction counters are the
  engine's telemetry.

* **Eviction under memory pressure.**  An optional ``cache_budget`` (total
  live context tokens across slots, a host-mirrored high-water mark)
  bounds cache occupancy: while over budget with more than one active
  slot, the largest-context slot is evicted and its request re-queued with
  its generated tokens folded into the prompt (greedy decode makes the
  replay token-identical), re-entering through the same ``match_round``
  admission at work-remaining priority.

The engine serves EVERY model family that implements the DecodeCache
serving contract (``init_cache`` -> ``repro.models.cache`` layouts,
``prefill_chunk``, per-row ``decode_step``) — dense, hybrid, ssm, vlm,
audio; a family missing a method fails construction with a structured
``UnsupportedFamilyError``.

**Failure model** (mirrors ``repro.runtime.fault_tolerance``'s (a)/(b)/(c)
taxonomy, mapped onto launches):

(a) *Hard launch failure.*  A decode or prefill launch that exhausts its
    ``FaultPolicy`` bounded retries raises ``LaunchFailedError`` out of
    :meth:`Engine.run` for a job-level restart —
    :meth:`Engine.restart` rebuilds a replica on a (possibly shrunken)
    mesh from the latest params checkpoint via
    ``repro.runtime.elastic.serving_restore`` (pure PWS re-plan, no
    per-tensor migration; caches rebuild empty and requests replay).

(b) *Transient launch fault / poisoned row.*  Every launch runs under
    bounded retry with exponential backoff and seeded jitter (the one
    sanctioned nondeterminism — it perturbs wall time, never tokens;
    retries are sound because faults fire BEFORE the launch commits its
    donated buffers).  A row whose logits go non-finite is bisected by the
    per-row validity vector the decode step returns: only the poisoned
    slot is evicted and its request re-queued through ``match_round`` —
    token emission for that step is suppressed, so greedy replay (from
    the last row snapshot when one exists, else the full effective
    prompt) keeps the request's tokens identical to a clean run.

(c) *Stragglers + graceful degradation.*  Each launch's wall time feeds a
    ``StragglerMonitor`` watchdog (z-score flagging, flagged samples
    excluded from the window); flagged launches and failed attempts both
    count as fault events.  When ``degrade_after`` events land within
    ``degrade_window`` engine iterations, the active-slot limit shrinks by
    one (existing occupants drain naturally; admission just stops filling
    the top slot), and after ``heal_after`` healthy iterations it probes
    back up one slot at a time.  Degradation changes scheduling only —
    greedy tokens stay identical.

(d) *Replica loss.*  Handled one level up.  A ``LaunchFailedError`` that
    escalates out of :meth:`Engine.run` / :meth:`Engine.step` marks the
    whole replica dead at the fleet tier: ``repro.launch.router`` salvages
    the replica's queue and in-flight requests (each with its last
    host-staged snapshot — host memory survives device loss), re-queues
    them router-wide, and spins up a replacement through
    checkpoint-streamed :meth:`Engine.restart` on a re-planned (possibly
    shrunken) mesh.  This engine owns tiers (a)–(c) only; it never
    catches its own escalation.

Row snapshots (``models.cache.snapshot_row``/``restore_row``) are taken on
a ``snapshot_every`` generated-token cadence, host-staged per request:
recovery and ``cache_budget`` pressure eviction both resume from the last
snapshot plus a short greedy replay instead of whole-residency recompute.
A deterministic ``FaultInjector`` plan (``--inject`` / ``REPRO_FAULTS``,
grammar ``decode@12=raise,prefill@3=delay:0.2,slot@2=nan_logits``) drives
the same faults through tests, the CI smoke arm, and the bench recovery
arm — recovery is asserted invisible to numerics.

Numerics contract: with greedy decoding the engine's per-request tokens are
IDENTICAL to running each request alone through the lockstep path (same
jitted model functions, write-before-attend keeps parked rows harmless) —
``tests/test_engine.py`` asserts this request-for-request: dense fp32 and
int8, hybrid, and ssm.  (Recurrent-state families are exact because parked
rows carry identity state updates; hybrid needs ``chunk`` >= the longest
prompt — the LRU h0-fold reassociates across chunk boundaries — and ssm
needs prompt/chunk lengths aligned to ``cfg.ssm_chunk``.)
"""
from __future__ import annotations

import argparse
import logging
import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pws
from repro.core.sharding_hints import axis_rules
from repro.launch.serve import Request, Server
from repro.models import cache as dcache
from repro.models.base import Model, RunOptions, UnsupportedFamilyError
from repro.runtime.fault_tolerance import (
    FaultInjector,
    FaultPolicy,
    LaunchFailedError,
    StragglerMonitor,
    export_fault_counters,
)

log = logging.getLogger("repro.engine")


class SlotScheduler:
    """Deterministic slot↔request matcher on the PWS §4.7 round discipline.

    One :meth:`assign` call drains as many matching rounds as the idle-slot
    supply allows.  Each round goes through :func:`repro.core.pws.match_round`
    — idle slots (thieves, ranked by slot index) matched positionally to the
    queued requests holding the round's best priority (victims, by queue
    index) — then enforces and ASSERTS the paper's bounds: at most ``p - 1``
    matches per round (Obs. 4.3 at the round's priority; ``p`` = slot
    count), and non-increasing round priorities within the drain (§4.1).
    Counters double as the engine's telemetry.
    """

    def __init__(self, n_slots: int):
        self.p = max(int(n_slots), 1)
        self.counters = {
            "matches": 0,        # requests admitted into slots (steals)
            "rounds": 0,         # matching rounds run
            "evictions": 0,      # slot releases (stop / capacity)
            "pressure_evictions": 0,  # budget evictions (request re-queued)
            "drains": 0,         # router-level slot releases (migration/leave)
            "max_round_matches": 0,
            # fault-tolerance telemetry (engine-incremented)
            "retries": 0,             # launch retry attempts
            "faults_injected": 0,     # mirrored from the FaultInjector
            "slots_poisoned": 0,      # non-finite rows bisected + evicted
            "snapshots_taken": 0,     # host-staged row snapshots
            "snapshot_restores": 0,   # re-admissions resumed from a snapshot
            "stragglers": 0,          # watchdog-flagged slow launches
            "degradations": 0,        # active-slot-limit shrinks
            "degraded_iters": 0,      # iterations run below full slot count
        }

    def assign(self, idle_slots, queue, priority):
        """Match ``idle_slots`` to entries of ``queue`` (a sequence of
        requests; ``priority(r)`` = work remaining).  Returns the matches as
        ``[(slot, queue_index), ...]`` in match order; the caller admits and
        pops.  Deterministic in its inputs."""
        bound = max(self.p - 1, 1)
        idle = [(s, s) for s in sorted(idle_slots)]
        taken: set[int] = set()
        assignments: list[tuple[int, int]] = []
        last_best: Optional[int] = None
        while idle:
            heads = [(i, priority(r)) for i, r in enumerate(queue)
                     if i not in taken]
            best, pairs = pws.match_round(idle, heads)
            if best is None:
                break
            # Obs. 4.3: at most p-1 tasks of the round's priority are stolen
            pairs = pairs[:bound]
            assert len(pairs) <= bound, \
                "PWS bounded-steals-per-round invariant violated"
            assert last_best is None or best <= last_best, \
                "PWS round priorities must be non-increasing"
            last_best = best
            self.counters["rounds"] += 1
            self.counters["max_round_matches"] = max(
                self.counters["max_round_matches"], len(pairs))
            for pair, qidx in pairs:
                idle.remove(pair)
                taken.add(qidx)
                assignments.append((pair[1], qidx))
                self.counters["matches"] += 1
        return assignments


@dataclass
class _Slot:
    """One decode lane of the fixed-size batch."""
    req: Optional[Request] = None
    state: str = "empty"      # empty | prefill | decode
    filled: int = 0           # cache positions written (prefill progress)
    pos: int = 0              # next decode position (== tokens in context)
    last_token: int = 0
    # engine iteration of the slot's last progress (admission, chunk, or
    # decoded token) — the recency stamp the "coldest" eviction policy keys on
    last_step: int = -1
    # the residency's effective prompt: the request's prompt plus any
    # tokens generated before a pressure eviction (replayed on re-admit)
    prompt: Optional[np.ndarray] = None
    stats: dict = field(default_factory=dict)

    @property
    def context(self) -> int:
        """Live cache tokens this slot holds (budget accounting)."""
        return self.pos if self.state == "decode" else self.filled


class Engine(Server):
    """Continuous-batching engine over the lockstep :class:`Server`'s model
    setup (same jitted prefill/decode; adds the per-row decode step and the
    batched chunked-prefill step).  Serves every family implementing the
    DecodeCache contract; ``cache_budget`` (total live context tokens) turns
    on eviction under memory pressure."""

    def __init__(self, cfg, mesh, *, max_batch: int = 4, max_len: int = 256,
                 chunk: int = 16, eos_id: Optional[int] = None,
                 cache_budget: Optional[int] = None,
                 evict_policy: str = "largest",
                 fault_policy: Optional[FaultPolicy] = None,
                 injector: Optional[FaultInjector] = None,
                 snapshot_every: int = 16,
                 degrade_after: int = 3, degrade_window: int = 8,
                 heal_after: int = 16,
                 opts: RunOptions = RunOptions()):
        super().__init__(cfg, mesh, max_batch=max_batch, max_len=max_len,
                         opts=opts)
        for name in ("init_cache", "prefill_chunk", "decode_step"):
            impl = getattr(type(self.model), name, None)
            if impl is None or impl is getattr(Model, name, None):
                raise UnsupportedFamilyError(cfg.family, name)
        self.chunk = int(chunk)
        self.eos_id = eos_id
        self.cache_budget = cache_budget
        if evict_policy not in ("largest", "coldest"):
            raise ValueError(f"unknown evict_policy {evict_policy!r}: "
                             "expected 'largest' or 'coldest'")
        self.evict_policy = evict_policy
        self.fault_policy = fault_policy or FaultPolicy()
        self.injector = FaultInjector.from_env() if injector is None \
            else injector
        self.snapshot_every = int(snapshot_every)
        self.degrade_after = int(degrade_after)
        self.degrade_window = int(degrade_window)
        self.heal_after = int(heal_after)
        # per-launch watchdog: wall-time z-scores over the dispatch window
        # (injected delays sleep inside it); flagged launches count toward
        # the degradation window.  On-device stalls past dispatch need a
        # block_until_ready probe — out of scope on this backend.
        self.watchdog = StragglerMonitor(window=32, k_sigma=4.0,
                                         min_samples=5)
        self.scheduler = SlotScheduler(max_batch)
        # host-side staging for modality-frontend inputs (VLM/audio): one
        # full-batch buffer per spec, rows written at admission and shipped
        # with every first-chunk launch
        specs = self.model.batch_extras_specs(max_batch, max_len)
        self._extras_host = {
            k: np.zeros(s.shape, s.dtype) for k, s in specs.items()
        } or None

        from repro.kernels import autotune as kernel_autotune
        from repro.kernels import policy as kernel_policy
        prov = kernel_autotune.provenance()
        log.info("engine policy %s | autotune table %s (%d tuned plan(s), "
                 "%s) | faults %s | retry max=%d snapshot_every=%d",
                 kernel_policy.current().describe(), prov["table"],
                 prov["tuned_plans"],
                 "present" if prov["table_exists"] else "absent",
                 self.injector.describe(), self.fault_policy.max_retries,
                 self.snapshot_every)

        def decode_rows(params, tokens, pos, cache, poison):
            logits, cache = self.model.decode_step(params, tokens, pos, cache)
            # injected poison lands here (a traced mask — no recompile);
            # the per-row finiteness vector is the bisection signal the
            # host uses to evict exactly the corrupt slot
            logits = jnp.where(poison[:, None], jnp.float32(jnp.nan), logits)
            ok = jnp.all(jnp.isfinite(logits), axis=-1)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return nxt, ok, cache

        def chunk_step(params, tokens, offset, lens, cache, extras, *, first):
            logits, cache = self.model.prefill_chunk(
                params, tokens, offset, cache, first=first, lens=lens,
                extras=extras)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return nxt, cache

        import functools
        self._decode_rows = jax.jit(decode_rows, donate_argnums=(3,))
        self._chunk_first = jax.jit(
            functools.partial(chunk_step, first=True), donate_argnums=(4,))
        self._chunk_cont = jax.jit(
            functools.partial(chunk_step, first=False), donate_argnums=(4,))
        self.begin([])  # stats()/adopt() are valid before the first run

    @classmethod
    def restart(cls, cfg, mesh, ckpt_dir, **kw):
        """Failure model (a): rebuild a serving replica on ``mesh`` — the
        same mesh, or a shrunken one after losing hosts — with params from
        the latest checkpoint via ``elastic.serving_restore``.  The PWS
        planner is deterministic in the mesh, so this is a pure re-plan +
        device_put: no per-tensor migration, and the restored replica's
        logits are identical to the original's.  Caches rebuild empty;
        in-flight requests re-enter through admission and replay."""
        from repro.checkpoint import CheckpointManager
        from repro.runtime import elastic

        eng = cls(cfg, mesh, **kw)
        aparams = jax.eval_shape(lambda: eng.model.init(jax.random.key(0)))
        with mesh, axis_rules(eng.rules, mesh):
            step, params, _ = elastic.serving_restore(
                CheckpointManager(ckpt_dir), aparams, mesh)
        eng.params = params
        log.info("engine restarted from step-%d checkpoint on mesh %s",
                 step, dict(mesh.shape))
        return eng

    # -- scheduling ----------------------------------------------------------
    @staticmethod
    def _effective_prompt(req: Request) -> np.ndarray:
        """The token sequence a residency must prefill: the prompt, plus —
        after a pressure eviction — every token already generated (greedy
        decode replays them deterministically)."""
        prompt = np.asarray(req.prompt, np.int32)
        if req.out:
            prompt = np.concatenate([prompt,
                                     np.asarray(req.out, np.int32)])
        return prompt

    @staticmethod
    def _work_remaining(req: Request, filled: int = 0) -> int:
        """The PWS priority: context tokens still to prefill plus tokens
        still to generate — larger tasks first, the size-based order."""
        return ((len(req.prompt) + len(req.out) - filled)
                + (req.max_new - len(req.out)))

    def _evict(self, i: int):
        self.slots[i] = _Slot()
        self.scheduler.counters["evictions"] += 1

    # -- fault handling ------------------------------------------------------
    def _launch(self, kind: str, fn, *args):
        """Run one jitted launch under the failure model: the injector may
        raise or delay it, failures retry up to ``FaultPolicy.max_retries``
        with seeded exponential backoff, and the watchdog z-scores its wall
        time.  Retrying the same arguments is sound because faults fire
        before the launch commits its donated buffers.  Exhausted retries
        escalate as :class:`LaunchFailedError` (failure model (a))."""
        ordinal = self._launch_seq[kind]
        self._launch_seq[kind] = ordinal + 1
        counters = self.scheduler.counters
        last: Optional[BaseException] = None
        for attempt in range(self.fault_policy.max_retries + 1):
            if attempt:
                counters["retries"] += 1
                time.sleep(self.fault_policy.backoff(attempt - 1,
                                                     self._fault_rng))
            t0 = time.time()
            try:
                self.injector.before_launch(kind, ordinal)
                out = fn(*args)
            except Exception as e:  # noqa: BLE001 — any launch fault retries
                last = e
                self._note_fault()
                log.warning("%s launch %d attempt %d failed: %r",
                            kind, ordinal, attempt, e)
                continue
            if self.watchdog.observe(time.time() - t0):
                counters["stragglers"] += 1
                self._note_fault()
                log.warning("straggler %s launch %d", kind, ordinal)
            return out
        raise LaunchFailedError(kind, ordinal,
                                self.fault_policy.max_retries + 1) from last

    def _note_fault(self):
        """One fault event (failed attempt, straggler, poisoned row) lands
        in the degradation window."""
        self._recent_faults.append(self._iter)
        self._last_fault_iter = self._iter

    def _update_degradation(self):
        """Failure model (c): shrink the active-slot limit after
        ``degrade_after`` fault events inside ``degrade_window`` iterations
        (occupied slots above the limit drain naturally — only admission
        shrinks), probe back up one slot per ``heal_after`` healthy
        iterations.  Scheduling-only: greedy tokens are unaffected."""
        counters = self.scheduler.counters
        cutoff = self._iter - self.degrade_window
        self._recent_faults = [t for t in self._recent_faults if t >= cutoff]
        if (len(self._recent_faults) >= self.degrade_after
                and self._active_limit > 1):
            self._active_limit -= 1
            self._recent_faults.clear()  # fresh evidence before the next cut
            counters["degradations"] += 1
            log.warning("degraded to %d/%d active slots",
                        self._active_limit, self.max_batch)
        elif (self._active_limit < self.max_batch
                and self._iter - self._last_fault_iter >= self.heal_after):
            self._active_limit += 1
            self._last_fault_iter = self._iter  # one probe per heal window
        if self._active_limit < self.max_batch:
            counters["degraded_iters"] += 1
        self._iter += 1

    def _poisoned(self, i: int):
        """Failure model (b), after bisection: slot ``i``'s row went
        non-finite.  Only this slot is evicted; its request re-queues
        through ``match_round`` and resumes from its last snapshot (or a
        full effective-prompt replay) — its emitted tokens stay exactly the
        clean run's."""
        req = self.slots[i].req
        self.slots[i] = _Slot()
        self.queue.append(req)
        self.scheduler.counters["slots_poisoned"] += 1
        self._note_fault()
        log.warning("poisoned slot %d: evicted uid=%d for replay", i,
                    req.uid)

    def _take_snapshot(self, i: int):
        """Host-stage row ``i`` as its request's resume point (cadence:
        every ``snapshot_every`` generated tokens)."""
        s = self.slots[i]
        self._snaps[s.req.uid] = {
            "row": dcache.snapshot_row(self.cache, i),
            "pos": s.pos, "n_out": len(s.req.out), "last": s.last_token,
        }
        self.scheduler.counters["snapshots_taken"] += 1

    def _emit(self, i: int, tok: int) -> bool:
        """Record one generated token for slot ``i``; returns True (and
        evicts) when the request stops: max_new reached, EOS, or the cache
        capacity exhausted."""
        slot = self.slots[i]
        r = slot.req
        r.out.append(tok)
        # slot.pos is the NEXT write position: at max_len the cache is full
        stop = (len(r.out) >= r.max_new
                or (self.eos_id is not None and tok == self.eos_id)
                or slot.pos >= self.max_len)
        if stop:
            self._completed.append(r)
            self._snaps.pop(r.uid, None)  # resume point no longer needed
            self._evict(i)
        return stop

    # -- engine loop ---------------------------------------------------------
    def _admit(self):
        # degradation shrinks the admissible slot range; occupants above the
        # limit keep running until they finish on their own
        idle = [i for i, s in enumerate(self.slots[:self._active_limit])
                if s.state == "empty"]
        if not idle or not self.queue:
            return
        matched = self.scheduler.assign(idle, self.queue,
                                        self._work_remaining)
        # pop in descending queue order so earlier indices stay valid
        for slot_id, qidx in sorted(matched, key=lambda m: -m[1]):
            req = self.queue.pop(qidx)
            snap = self._snaps.get(req.uid)
            if snap is not None:
                # resume from the last row snapshot: restore the row slices
                # wholesale (cursors, slabs, scales, recurrent state +
                # validity), truncate the output back to the snapshot point,
                # and replay the short greedy tail — no prefill at all
                self.cache = dcache.restore_row(self.cache, slot_id,
                                                snap["row"])
                del req.out[snap["n_out"]:]
                self.slots[slot_id] = _Slot(req=req, state="decode",
                                            filled=snap["pos"],
                                            pos=snap["pos"],
                                            last_token=snap["last"],
                                            last_step=self._iter)
                self.scheduler.counters["snapshot_restores"] += 1
                continue
            self.slots[slot_id] = _Slot(req=req, state="prefill", filled=0,
                                        last_step=self._iter,
                                        prompt=self._effective_prompt(req))
            # the row's per-row lengths/validity reset here; slabs are NOT
            # zeroed — write-before-attend makes stale tokens unreachable
            self.cache = dcache.reset_row(self.cache, slot_id)
            if self._extras_host is not None and req.extras:
                for key, val in req.extras.items():
                    self._extras_host[key][slot_id] = val

    def _advance_prefill(self):
        """Advance EVERY prefilling slot by one fixed-size chunk, batched:
        one padded full-batch launch for first chunks (all at offset 0 —
        modality frontends and int8 scale calibration run there, masked to
        live rows) and one for continuations, each with per-row offsets and
        valid-token ``lens`` (0 parks a row: decode lanes park at ``pos``,
        so their garbage writes land where their own next token lands
        first).  A slot whose chunk finishes its prompt flips to decode
        with the first generated token in hand."""
        first = [i for i, s in enumerate(self.slots)
                 if s.state == "prefill" and s.filled == 0]
        cont = [i for i, s in enumerate(self.slots)
                if s.state == "prefill" and s.filled > 0]
        for group, fn in ((first, self._chunk_first),
                          (cont, self._chunk_cont)):
            if not group:
                continue
            toks = np.zeros((self.max_batch, self.chunk), np.int32)
            offset = np.zeros((self.max_batch,), np.int32)
            lens = np.zeros((self.max_batch,), np.int32)
            for i, s in enumerate(self.slots):
                if i in group:
                    end = min(s.filled + self.chunk, len(s.prompt))
                    toks[i, :end - s.filled] = s.prompt[s.filled:end]
                    offset[i] = s.filled
                    lens[i] = end - s.filled
                else:  # park: overwritten before anything attends it
                    offset[i] = s.context
            extras = None
            if fn is self._chunk_first and self._extras_host is not None:
                extras = {k: jnp.asarray(v)
                          for k, v in self._extras_host.items()}
            nxt, self.cache = self._launch(
                "prefill", fn, self.params, jnp.asarray(toks),
                jnp.asarray(offset), jnp.asarray(lens), self.cache, extras)
            nxt = np.asarray(nxt)
            self._n_chunks += 1
            self._n_chunk_rows += len(group)
            for i in group:
                slot = self.slots[i]
                slot.filled += int(lens[i])
                slot.last_step = self._iter
                if slot.filled >= len(slot.prompt):
                    slot.state = "decode"
                    slot.pos = len(slot.prompt)
                    self.cache = dcache.set_row_valid(self.cache, i, True)
                    tok = int(nxt[i])
                    slot.last_token = tok
                    self._emit(i, tok)

    def _decode_step(self):
        """One batched per-row decode step over every decoding slot.  Rows
        not decoding still ride along (fixed shapes — no recompile): their
        garbage k/v writes park at the next position their own prefill (or
        admission) will overwrite before anything attends it — the
        write-before-attend discipline that makes lane coexistence safe.
        The step returns a per-row validity vector; a decoding row that
        comes back non-finite is bisected and evicted (:meth:`_poisoned`)
        with its token suppressed, and surviving rows snapshot on the
        ``snapshot_every`` cadence."""
        toks = np.zeros((self.max_batch, 1), np.int32)
        pos = np.zeros((self.max_batch,), np.int32)
        decoding = []
        for i, s in enumerate(self.slots):
            if s.state == "decode":
                toks[i, 0] = s.last_token
                pos[i] = s.pos
                decoding.append(i)
            else:  # park: overwritten by the slot's next prefill chunk
                pos[i] = s.context
        poison = np.zeros((self.max_batch,), bool)
        poison[self.injector.poison_rows(decoding)] = True
        nxt, ok, self.cache = self._launch(
            "decode", self._decode_rows, self.params, jnp.asarray(toks),
            jnp.asarray(pos), self.cache, jnp.asarray(poison))
        nxt, ok = np.asarray(nxt), np.asarray(ok)
        self._n_decode_steps += 1
        for i in decoding:
            if not ok[i]:
                self._poisoned(i)
                continue
            s = self.slots[i]
            s.pos += 1
            s.last_step = self._iter
            tok = int(nxt[i])
            s.last_token = tok
            if (not self._emit(i, tok) and self.snapshot_every
                    and len(s.req.out) % self.snapshot_every == 0):
                self._take_snapshot(i)

    def _apply_pressure(self):
        """Evict while the host-mirrored live-context total exceeds
        ``cache_budget`` and more than one slot is active.  The victim is
        the ``evict_policy`` pick — ``largest`` (default): the
        largest-context slot, the budget-greedy choice; ``coldest``: the
        least-recently-progressed slot by its ``last_step`` stamp, the
        recency choice that spares hot decode lanes.  Either way the
        request re-queues with generated tokens folded into the prompt
        (replayed exactly under greedy decode) — or, when the request
        holds a row snapshot, resumes from it at re-admission
        (host-staged, so it costs no budget).  A lone active slot never
        evicts — progress is guaranteed whatever the budget."""
        if self.cache_budget is None:
            return
        while True:
            active = [(s.context, i) for i, s in enumerate(self.slots)
                      if s.state != "empty"]
            if (len(active) <= 1
                    or sum(c for c, _ in active) <= self.cache_budget):
                return
            if self.evict_policy == "coldest":
                _, victim = min((self.slots[i].last_step, i)
                                for _, i in active)
            else:
                _, victim = max(active)
            req = self.slots[victim].req
            self.slots[victim] = _Slot()
            self.queue.append(req)
            self.scheduler.counters["pressure_evictions"] += 1

    # -- step API (the fleet tier's seam) ------------------------------------
    def begin(self, requests: list[Request] = ()):
        """Start a serving run: reset per-run scheduler/cache/fault state
        and queue ``requests``.  ``begin``/``step``/``busy``/``finish`` are
        the seam the fleet tier (``repro.launch.router``) drives — it
        interleaves :meth:`step` across replicas and moves requests between
        them with :meth:`drain_slot`/:meth:`adopt`/:meth:`salvage`;
        :meth:`run` composes the same four calls for the single-replica
        path."""
        self.queue: list[Request] = list(requests)
        self.scheduler = SlotScheduler(self.max_batch)  # per-run telemetry
        self.slots = [_Slot() for _ in range(self.max_batch)]
        self.cache = self.model.init_cache(self.max_batch, self.max_len)
        self._completed: list[Request] = []
        self._n_chunks = self._n_decode_steps = self._n_chunk_rows = 0
        # fault state is per-run: launch ordinals restart (so a plan's
        # decode@N names the N-th launch of THIS run), the backoff rng
        # re-seeds (reproducible delay sequence), snapshots/degradation
        # start clean
        self._launch_seq = {"decode": 0, "prefill": 0}
        self._injected_before = self.injector.counters["faults_injected"]
        self._fault_rng = self.fault_policy.make_rng()
        self._snaps: dict[int, dict] = {}
        self._recent_faults: list[int] = []
        self._iter = 0
        self._last_fault_iter = -(10 ** 9)
        self._active_limit = self.max_batch
        self.busy_s = 0.0
        self._t0 = time.time()

    def busy(self) -> bool:
        """True while this replica still owes work: queued requests or any
        occupied slot."""
        return bool(self.queue) or any(s.state != "empty"
                                       for s in self.slots)

    def step(self):
        """One engine iteration: admit, batched prefill chunks, batched
        per-row decode, pressure eviction, degradation bookkeeping.  Wall
        time accrues to this replica's ``busy_s`` clock — in production
        each replica is its own accelerator, so the fleet makespan is the
        max of these clocks, which is how the router reports fleet
        throughput when replicas time-share one test device."""
        t0 = time.time()
        with self.mesh, axis_rules(self.rules, self.mesh):
            self._admit()
            self._advance_prefill()
            if any(s.state == "decode" for s in self.slots):
                self._decode_step()
            self._apply_pressure()
            self._update_degradation()
        self.busy_s += time.time() - t0

    def finish(self) -> dict:
        """Seal the run's counters (the injected-fault mirror lands in the
        telemetry) and return the final :meth:`stats` view."""
        self.scheduler.counters["faults_injected"] = (
            self.injector.counters["faults_injected"]
            - self._injected_before)
        return self.stats()

    def stats(self) -> dict:
        """The engine's structured observability surface — scheduler
        counters, fault counters (``runtime.fault_tolerance`` keys), the
        degradation-window state, slot occupancy, and the remaining-work
        load signal.  The router's health scoring and load shedding read
        THIS, never private attributes; live mid-run reads are supported
        (the injected-fault mirror refreshes here)."""
        counters = self.scheduler.counters
        counters["faults_injected"] = (
            self.injector.counters["faults_injected"]
            - self._injected_before)
        faults = export_fault_counters(counters)
        return {
            "scheduler": {k: v for k, v in counters.items()
                          if k not in faults},
            "faults": faults,
            "degradation": {
                "active_limit": self._active_limit,
                "max_batch": self.max_batch,
                "degraded": self._active_limit < self.max_batch,
                "recent_fault_events": len(self._recent_faults),
                "iter": self._iter,
            },
            "occupancy": {
                "queued": len(self.queue),
                "prefilling": sum(s.state == "prefill" for s in self.slots),
                "decoding": sum(s.state == "decode" for s in self.slots),
                "free": sum(s.state == "empty" for s in self.slots),
            },
            "work_remaining": self.work_remaining_total(),
            "launches": dict(self._launch_seq),
            "busy_s": self.busy_s,
            "decode_compilations": self._decode_rows._cache_size(),
        }

    # -- fleet-tier request movement -----------------------------------------
    def work_remaining_total(self) -> int:
        """Queued + in-flight work remaining — the router's load signal
        (same units as the PWS admission priority)."""
        w = sum(self._work_remaining(r) for r in self.queue)
        for s in self.slots:
            if s.req is not None:
                w += self._work_remaining(s.req, s.context)
        return w

    def drain_slot(self, i: int, fresh: bool = True) -> \
            tuple[Request, Optional[dict]]:
        """Fleet-tier release of slot ``i`` (migration or replica leave):
        frees the slot and returns ``(request, resume_snapshot_or_None)``.
        A live drain of a decoding row stages a FRESH snapshot first, so
        migration never rolls the request behind its current position —
        without that, a migration per round could re-lose exactly the
        token each round gains (no fleet progress).  ``fresh=False`` is
        the death path (``salvage``): the device may be gone, so re-entry
        falls back to the last cadence snapshot (plus the post-snapshot
        greedy tail) or, with none staged, replays the effective prompt —
        token-exact either way."""
        s = self.slots[i]
        req = s.req
        if fresh and s.state == "decode":
            self._take_snapshot(i)
        snap = self._snaps.pop(req.uid, None)
        self.slots[i] = _Slot()
        self.scheduler.counters["drains"] += 1
        return req, snap

    def withdraw_queued(self, qidx: int) -> tuple[Request, Optional[dict]]:
        """Fleet-tier removal of queued request ``qidx`` (rebalancing): no
        cache state moves — just the request and any staged snapshot it
        carries from an earlier residency."""
        req = self.queue.pop(qidx)
        return req, self._snaps.pop(req.uid, None)

    def adopt(self, req: Request, snap: Optional[dict] = None):
        """Accept a request routed (or migrated) to this replica.  ``snap``
        is a host-staged resume entry whose row may have been captured on a
        DIFFERENT replica — row slices carry no slot or replica identity,
        but the layout must match, so it is validated against this
        engine's cache before staging."""
        if snap is not None:
            dcache.snapshot_compatible(self.cache, snap["row"])
            self._snaps[req.uid] = snap
        self.queue.append(req)

    def salvage(self) -> list[tuple[Request, Optional[dict]]]:
        """Everything this replica still owes, for router-wide re-queue
        after a death or a leave: queued then slotted requests, each with
        its last host-staged snapshot when one exists (host memory
        survives device loss).  Leaves the engine empty."""
        out = [(r, self._snaps.pop(r.uid, None)) for r in self.queue]
        self.queue = []
        for i, s in enumerate(self.slots):
            if s.req is not None:
                out.append(self.drain_slot(i, fresh=False))
        return out

    def run(self, requests: list[Request]) -> dict:
        """Serve ``requests`` to completion with continuous batching; greedy
        decode.  Returns wall/tokens/telemetry; per-request tokens land in
        ``request.out`` (identical to running each request alone through the
        lockstep path)."""
        self.begin(requests)
        while self.busy():
            self.step()
        stats = self.finish()
        dt = time.time() - self._t0
        n_tokens = sum(len(r.out) for r in requests)
        return {
            "wall_s": dt,
            "busy_s": self.busy_s,
            "tokens": n_tokens,
            "tok_per_s": n_tokens / max(dt, 1e-9),
            "decode_steps": self._n_decode_steps,
            "prefill_chunks": self._n_chunks,
            "prefill_chunk_rows": self._n_chunk_rows,
            "completed": {r.uid: len(r.out) for r in self._completed},
            "telemetry": dict(self.scheduler.counters),
            "stats": stats,
        }


def check_lockstep_parity(engine: Engine, requests: list[Request]) -> bool:
    """Row-for-row acceptance check: each request run ALONE through the
    lockstep jitted path must reproduce the engine's tokens exactly."""
    ok = True
    for r in requests:
        alone = Request(r.uid, r.prompt, max_new=r.max_new)
        batch = {"tokens": jnp.asarray(r.prompt)[None]}
        if r.extras:
            for key, val in r.extras.items():
                batch[key] = jnp.asarray(val)[None]
        with engine.mesh, axis_rules(engine.rules, engine.mesh):
            logits, cache = engine._prefill(engine.params, batch)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            for step in range(r.max_new):
                tok = int(nxt[0])
                alone.out.append(tok)
                if engine.eos_id is not None and tok == engine.eos_id:
                    break
                if len(alone.out) >= r.max_new:
                    break
                pos = jnp.asarray(len(r.prompt) + step, jnp.int32)
                nxt, cache = engine._decode(engine.params, nxt[:, None], pos,
                                            cache)
        if alone.out != r.out:
            ok = False
            log.error("parity FAIL uid=%d alone=%s engine=%s", r.uid,
                      alone.out, r.out)
    return ok


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--chunk", type=int, default=16)
    ap.add_argument("--prompt-align", type=int, default=1,
                    help="round generated prompt lengths up to a multiple "
                         "of N (ssm exactness needs chunk boundaries on "
                         "cfg.ssm_chunk multiples)")
    ap.add_argument("--cache-budget", type=int, default=0,
                    help="total live context tokens across slots before "
                         "pressure eviction kicks in (0 = unbounded)")
    ap.add_argument("--evict-policy", default="largest",
                    choices=("largest", "coldest"),
                    help="pressure-eviction victim: largest context "
                         "(default) or coldest = least-recently-progressed "
                         "slot by its last-step stamp")
    ap.add_argument("--check-lockstep", action="store_true",
                    help="re-run each request alone through the lockstep "
                         "path and assert row-for-row token parity")
    ap.add_argument("--inject", default="",
                    help="deterministic fault plan, e.g. 'decode@12=raise,"
                         "prefill@3=delay:0.2,slot@2=nan_logits' (default: "
                         "the REPRO_FAULTS env plan)")
    ap.add_argument("--snapshot-every", type=int, default=16,
                    help="host-stage a row snapshot every N generated "
                         "tokens (0 = off; recovery then replays the full "
                         "effective prompt)")
    ap.add_argument("--impl", default="",
                    help="execution-policy impl map (see serve.py docstring)")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO, format="%(message)s")

    if args.impl:
        from repro.kernels import policy
        impl, variants = policy.parse_impl_spec(args.impl)
        policy.install(policy.ambient().with_(impl=impl, variants=variants))

    from repro.configs import get_config, get_smoke_config
    from repro.launch.mesh import make_debug_mesh
    cfg = get_smoke_config(args.arch) if args.reduced else get_config(args.arch)
    mesh = make_debug_mesh(tp=min(2, len(jax.devices())))
    engine = Engine(cfg, mesh, max_batch=args.slots, max_len=128,
                    chunk=args.chunk, opts=RunOptions(),
                    cache_budget=args.cache_budget or None,
                    evict_policy=args.evict_policy,
                    injector=(FaultInjector(args.inject) if args.inject
                              else None),
                    snapshot_every=args.snapshot_every)
    rng = np.random.default_rng(0)

    def plen():
        n = int(rng.integers(4, 24))
        return -(-n // args.prompt_align) * args.prompt_align

    specs = engine.model.batch_extras_specs(1, 128)

    def mk_extras():
        # one random modality-frontend row per request (VLM/audio stubs)
        return {k: rng.standard_normal(s.shape[1:]).astype(s.dtype)
                for k, s in specs.items()} or None

    reqs = [Request(i, rng.integers(3, cfg.vocab_size,
                                    plen()).astype(np.int32),
                    max_new=int(rng.integers(2, args.max_new + 1)),
                    extras=mk_extras())
            for i in range(args.requests)]
    out = engine.run(reqs)
    print(f"served {out['tokens']} tokens in {out['wall_s']:.2f}s "
          f"({out['tok_per_s']:.1f} tok/s; {out['decode_steps']} decode "
          f"steps, {out['prefill_chunks']} prefill chunks)")
    print(f"telemetry: {out['telemetry']}")
    if args.check_lockstep:
        assert check_lockstep_parity(engine, reqs), \
            "engine tokens diverge from the lockstep baseline"
        print("lockstep parity: OK")


if __name__ == "__main__":
    main()
