"""Step functions (train / prefill / decode) and their input specs.

``input_specs`` returns ShapeDtypeStruct stand-ins for every input of the
step being lowered — weak-type-correct, shardable, never allocated.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import Model, RunOptions, build_model
from repro.optim import AdamWConfig, adamw_init, adamw_update


@dataclass
class StepBundle:
    """A step function plus abstract inputs, ready to lower."""

    name: str
    fn: Callable
    args: tuple  # ShapeDtypeStruct pytrees
    kinds: tuple  # "params" | "opt" | "batch" | "cache" | "scalar" per arg


def abstract_params(model: Model) -> Any:
    return jax.eval_shape(lambda: model.init(jax.random.key(0)))


def abstract_opt_state(aparams: Any) -> Any:
    return jax.eval_shape(adamw_init, aparams)


def abstract_batch(model: Model, shape: ShapeConfig, *, with_labels: bool) -> dict:
    b, s = shape.global_batch, shape.seq_len
    batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if with_labels:
        batch["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    batch.update(model.batch_extras_specs(b, s))
    return batch


def abstract_cache(model: Model, shape: ShapeConfig) -> Any:
    return jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len)
    )


def make_train_step(model: Model, opt_cfg: Optional[AdamWConfig] = None):
    """Training step with optional gradient-accumulation microbatching
    (``model.opts.microbatches``): activations shrink k-fold, grads are
    accumulated in fp32 sharded like the parameters (single-writer shards —
    the paper's limited-access rule)."""
    opt_cfg = opt_cfg or AdamWConfig()
    n_micro = max(model.opts.microbatches, 1)

    def train_step(params, opt_state, batch):
        if n_micro == 1:
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
        else:
            def reshape(x):
                b = x.shape[0]
                return x.reshape(n_micro, b // n_micro, *x.shape[1:])

            micro = jax.tree.map(reshape, batch)

            def acc_fn(carry, mb):
                loss_acc, g_acc = carry
                l, g = jax.value_and_grad(model.loss)(params, mb)
                g_acc = jax.tree.map(lambda a, gg: a + gg.astype(jnp.float32), g_acc, g)
                return (loss_acc + l, g_acc), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(acc_fn, (jnp.zeros((), jnp.float32), g0), micro)
            loss = loss / n_micro
            grads = jax.tree.map(lambda g: g / n_micro, grads)
        new_params, new_opt, metrics = adamw_update(params, grads, opt_state, opt_cfg)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(model: Model, max_len: int):
    def prefill_step(params, batch):
        return model.prefill(params, batch, max_len)

    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, tokens, pos, cache):
        return model.decode_step(params, tokens, pos, cache)

    return decode_step


def build_step_bundle(cfg: ModelConfig, shape: ShapeConfig,
                      opts: Optional[RunOptions] = None) -> StepBundle:
    # kernel tiling resolves through the substrate inside Model.__init__
    # (repro.kernels.planner.resolve_run_options) — no duplicate policy here
    model = build_model(cfg, opts)
    aparams = abstract_params(model)

    if shape.kind == "train":
        fn = make_train_step(model)
        aopt = abstract_opt_state(aparams)
        abatch = abstract_batch(model, shape, with_labels=True)
        return StepBundle("train_step", fn, (aparams, aopt, abatch),
                          ("params", "opt", "batch"))
    if shape.kind == "prefill":
        fn = make_prefill_step(model, shape.seq_len)
        abatch = abstract_batch(model, shape, with_labels=False)
        return StepBundle("prefill_step", fn, (aparams, abatch), ("params", "batch"))
    if shape.kind == "decode":
        fn = make_decode_step(model)
        tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        acache = abstract_cache(model, shape)
        return StepBundle("serve_step", fn, (aparams, tokens, pos, acache),
                          ("params", "batch", "scalar", "cache"))
    raise ValueError(shape.kind)
