"""Batched serving driver: the LOCKSTEP baseline (wave-at-a-time).

Serving model: requests arrive with prompts; the server packs up to
``max_batch`` requests, prefills them (left-padded to a shared window), and
decodes in lockstep — one shared position per step — with per-row stopping:
a row that hits EOS / ``max_new`` stops appending (its lane still rides the
batch until the wave's slowest request ends — that burned work is exactly
what ``repro.launch.engine`` removes with per-row KV lengths, chunked
prefill, and PWS slot scheduling; this module stays as the simple baseline
and the parity oracle).  The KV cache is planned by the PWS planner
(kv-heads over tp when divisible, else sequence-sharded).

Backend selection is the ambient ``repro.kernels.policy`` execution
policy's call.  The ``--impl`` flag installs a process policy with the
grammar

    --impl op=backend[,op=backend]     e.g. --impl attention=pallas
    --impl '*=pallas'                  wildcard: every op
    --impl pallas                      bare backend == '*=backend'
    --impl op=backend:knob=value       variant knobs, e.g.
                                       --impl 'attention=pallas:kv_dtype=int8'
                                       --impl 'matmul=pallas:backend=classical'

where op is a registered kernel name (``scan`` | ``matmul`` | ``transpose``
| ``attention`` | ``fft``) or ``*``, and backend one of ``auto`` (registry
decides) | ``jnp`` | ``pallas``.  ``:knob=value`` suffixes set per-op
variant knobs on the policy (``attention kv_dtype=int8`` selects the
quantized KV cache; ``matmul backend=...``/``qkv_fused=true`` pin the
matmul schedule / fused projections).  Under a pallas attention policy,
prefill
dispatches as zero-offset self-attention and decode as a cached-attention
call where the step position flows into the kernel as a traced ``q_offset``
(and, causally, the KV valid-length) — per-step positions never retrace
either jit.  ``REPRO_IMPL`` (same grammar) sets the policy without a flag.

The lockstep server has NO failure handling by design — it is the simple
baseline and the parity oracle.  Fault injection, bounded launch retry,
row snapshots, and graceful degradation live in ``repro.launch.engine``
(see its "Failure model" section); ``REPRO_FAULTS`` / ``--inject`` plans
target the engine only.  The serving stack stacks in three tiers: this
``Server`` (lockstep oracle) → ``repro.launch.engine.Engine`` (continuous
batching + single-replica fault tolerance) → ``repro.launch.router.Router``
(a data-parallel fleet of engines with randomized-stealing routing,
replica death/respawn, and elastic join/leave) — each tier's guarantee is
token-identity with the tier below it.
"""
from __future__ import annotations

import argparse
import logging
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core import planner
from repro.core.sharding_hints import axis_rules, default_rules
from repro.models import build_model
from repro.models.base import RunOptions

log = logging.getLogger("repro.serve")


@dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (plen,) int32
    max_new: int = 16
    # generated tokens; under the engine's fault/pressure recovery, `out`
    # may be truncated back to a row-snapshot point and regenerated — greedy
    # decode makes the replay token-identical, so the final contents always
    # match a clean run
    out: list = field(default_factory=list)
    # modality-frontend inputs keyed by the model's batch_extras_specs()
    # (e.g. "image_embeds" / "audio_frames"), one row each, no batch axis
    extras: dict | None = None


class Server:
    def __init__(self, cfg, mesh, *, max_batch: int = 8, max_len: int = 256,
                 opts: RunOptions = RunOptions()):
        from repro.kernels import autotune as kernel_autotune
        from repro.kernels import planner as kernel_planner

        self.cfg = cfg
        self.mesh = mesh
        self.max_batch = max_batch
        self.max_len = max_len
        # serving tiles (q/kv blocks, kernel backend) resolve through the
        # kernel substrate; Server keeps the resolved copy for telemetry
        self.opts = kernel_planner.resolve_run_options(
            opts, head_dim=cfg.head_dim_, dtype=cfg.activation_dtype)
        # replay persisted measured tile plans for this device (no-op on a
        # cold cache).  Note "search" only fills the table from *eager*
        # dispatches — under jax.jit (all serving steps) it degrades to
        # replay; populate tables with benchmarks/autotune.py instead
        kernel_autotune.startup(self.opts.autotune)
        from repro.kernels import policy as kernel_policy
        prov = kernel_autotune.provenance()
        log.info("policy %s | autotune table %s (%d tuned plan(s), %s)",
                 kernel_policy.current().describe(), prov["table"],
                 prov["tuned_plans"],
                 "present" if prov["table_exists"] else "absent")
        self.model = build_model(cfg, self.opts)
        self.rules = default_rules(mesh)

        with mesh, axis_rules(self.rules, mesh):
            self.params = jax.jit(self.model.init)(jax.random.key(0))

        def prefill(params, batch):
            return self.model.prefill(params, batch, max_len)

        def decode(params, tokens, pos, cache):
            logits, cache = self.model.decode_step(params, tokens, pos, cache)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return nxt, cache

        self._prefill = jax.jit(prefill)
        self._decode = jax.jit(decode, donate_argnums=(3,))

    def run_batch(self, requests: list[Request],
                  eos_id: int | None = None) -> dict:
        """Prefill + greedy decode a batch of requests in lockstep, with
        per-row stop: a row stops appending once it hits ``max_new`` or
        ``eos_id``, and the wave ends early when every row is done.  Returns
        per-request completion counts alongside the wave totals."""
        b = len(requests)
        plen = max(len(r.prompt) for r in requests)
        toks = np.zeros((b, plen), np.int32)
        for i, r in enumerate(requests):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        mc = self.cfg
        rng = np.random.default_rng(0)
        if mc.family == "vlm":
            batch["image_embeds"] = jnp.asarray(rng.standard_normal(
                (b, mc.n_image_tokens, mc.d_model), dtype=np.float32))
        if mc.family == "audio":
            enc_len = max(int(self.max_len * mc.encoder_len_ratio), 16)
            batch["audio_frames"] = jnp.asarray(rng.standard_normal(
                (b, enc_len, mc.d_model), dtype=np.float32))

        t0 = time.time()
        done = [False] * b
        with self.mesh, axis_rules(self.rules, self.mesh):
            logits, cache = self._prefill(self.params, batch)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            max_new = max(r.max_new for r in requests)
            for step in range(max_new):
                for i, r in enumerate(requests):
                    if done[i]:
                        continue  # per-row stop: finished rows stop appending
                    tok = int(nxt[i])
                    r.out.append(tok)
                    if (len(r.out) >= r.max_new
                            or (eos_id is not None and tok == eos_id)):
                        done[i] = True
                if all(done):
                    break  # the wave drained early — skip the dead steps
                pos = jnp.asarray(plen + step, jnp.int32)
                nxt, cache = self._decode(self.params, nxt[:, None], pos, cache)
        dt = time.time() - t0
        n_tokens = sum(len(r.out) for r in requests)
        return {"wall_s": dt, "tokens": n_tokens,
                "tok_per_s": n_tokens / max(dt, 1e-9),
                "completed": {r.uid: len(r.out) for r in requests}}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--impl", default="",
                    help="execution-policy impl map, op=backend[,op=backend] "
                         "('*' wildcard; bare backend == '*=backend'): one "
                         "flag for every kernel-backend decision — replaces "
                         "--attention-impl/--matmul-impl (see module "
                         "docstring for the grammar)")
    args = ap.parse_args()

    if args.impl:
        from repro.kernels import policy
        impl, variants = policy.parse_impl_spec(args.impl)
        policy.install(policy.ambient().with_(impl=impl, variants=variants))

    cfg = get_smoke_config(args.arch) if args.reduced else get_config(args.arch)
    from repro.launch.mesh import make_debug_mesh
    mesh = make_debug_mesh(tp=min(2, len(jax.devices())))
    server = Server(cfg, mesh, max_batch=args.batch, max_len=128,
                    opts=RunOptions())
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(3, cfg.vocab_size, rng.integers(4, 20)).astype(np.int32),
                    max_new=args.max_new) for i in range(args.batch)]
    out = server.run_batch(reqs)
    print(f"served {out['tokens']} tokens in {out['wall_s']:.2f}s "
          f"({out['tok_per_s']:.1f} tok/s)")
    for r in reqs[:2]:
        print(f"req {r.uid}: {r.out[:8]}...")


if __name__ == "__main__":
    main()
