"""End-to-end LM training: data pipeline -> PWS-planned shardings ->
fault-tolerant loop with async checkpoints.

Presets:
  10m  (default) — ~10M params, a few hundred steps run in minutes on CPU
  100m           — ~100M params (the deliverable-scale config; same code)

  PYTHONPATH=src python examples/train_lm.py --preset 10m --steps 300
"""
import argparse
import logging
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs.base import ModelConfig
from repro.data import DataConfig
from repro.launch.mesh import make_debug_mesh
from repro.launch.train import train
from repro.models.base import RunOptions
from repro.optim import AdamWConfig

PRESETS = {
    "10m": ModelConfig(
        name="lm-10m", family="dense", n_layers=4, d_model=256, n_heads=4,
        n_kv_heads=2, d_ff=1024, vocab_size=8192, qk_norm=True,
    ),
    "100m": ModelConfig(
        name="lm-100m", family="dense", n_layers=10, d_model=640, n_heads=10,
        n_kv_heads=5, d_ff=2560, vocab_size=50304, qk_norm=True,
    ),
}


def main():
    logging.basicConfig(level=logging.INFO, format="%(message)s")
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="10m", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    mesh = make_debug_mesh(tp=1)
    out = train(
        cfg,
        mesh=mesh,
        steps=args.steps,
        data_cfg=DataConfig(global_batch=args.batch, seq_len=args.seq, seed=0),
        opts=RunOptions(remat="none"),
        opt_cfg=AdamWConfig(lr=6e-4),
        ckpt_dir=args.ckpt_dir,
        save_every=max(args.steps // 3, 1),
        log_every=20,
    )
    first = sum(out["losses"][:10]) / min(len(out["losses"]), 10)
    last = sum(out["losses"][-10:]) / min(len(out["losses"]), 10)
    print(f"\nloss {first:.4f} -> {last:.4f} over {args.steps} steps "
          f"({out['wall_s']:.0f}s, {out['wall_s']/args.steps*1e3:.0f} ms/step)")
    assert last < first, "training did not learn"


if __name__ == "__main__":
    main()
