"""The paper in action: run the simulated multicore and reproduce the
headline claims — PWS's deterministic priority-ordered steals, the <= p-1
steals-per-priority bound, and the block-miss (false sharing) advantage of
PWS + gapping over randomized work stealing.

  PYTHONPATH=src python examples/hbp_paper_demo.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core.algorithms import (
    BItoRMDirect,
    MSum,
    bi_to_rm_gapped_programs,
    strassen_program,
)
from repro.core.hbp import Memory
from repro.core.machine import Machine
from repro.core.pws import PWS
from repro.core.rws import RWS

P, M, B = 8, 512, 16


def run(make, sched):
    machine = Machine(P, M, B, scheduler=sched)
    progs = make()
    st = (machine.run_sequence(progs) if isinstance(progs, list)
          else machine.run(progs))
    return st


print(f"simulated multicore: p={P} cores, M={M} words cache, B={B} block\n")

# 1. scans under PWS: priority-ordered steals, <= p-1 per priority
st = run(lambda: MSum(1 << 14, Memory(B)), PWS())
spp = st.steals_per_priority()
print("M-Sum (scan), n=16384 under PWS:")
print(f"  steals={len(st.steals)} max-per-priority={max(spp.values())} (bound p-1={P-1})")
print(f"  cache misses={st.total_cache_misses()} block misses={st.total_block_misses()}")

# 2. false sharing: direct BI->RM vs the gapping technique, PWS vs RWS
print("\nBI->RM conversion (64x64), block misses (false sharing):")
for name, make in [("direct", lambda: BItoRMDirect(64, Memory(B))),
                   ("gapped", lambda: bi_to_rm_gapped_programs(64, Memory(B)))]:
    pws_bm = run(make, PWS()).total_block_misses()
    rws_bm = sum(run(make, RWS(seed=s)).total_block_misses() for s in range(5)) / 5
    print(f"  {name:7s}: PWS={pws_bm:5.1f}   RWS(mean of 5)={rws_bm:5.1f}")

# 3. Type-2 HBP: Strassen with MA collections and 7-way recursion
st = run(lambda: strassen_program(16, Memory(B), base=4), PWS())
print(f"\nStrassen 16x16 (Type 2 HBP): accesses={st.accesses} "
      f"steals={len(st.steals)} usurpations={st.usurpations}")
print("done — see benchmarks/table1.py for the full Table 1 sweep")
