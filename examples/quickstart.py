"""Quickstart: build an assigned architecture, inspect the PWS plan, run one
training step and a prefill+decode round trip — all on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core import planner
from repro.launch.mesh import make_debug_mesh
from repro.models import RunOptions, build_model

# 1. pick an architecture (reduced config for CPU)
cfg = get_smoke_config("qwen3-1.7b")
model = build_model(cfg, RunOptions(remat="none"))
params = model.init(jax.random.key(0))
n_params = sum(x.size for x in jax.tree.leaves(params))
print(f"arch={cfg.name} family={cfg.family} params={n_params:,}")

# 2. the PWS planner: resource-oblivious model, mesh-aware plan
mesh = make_debug_mesh(1, tp=1)
specs = planner.plan_params(jax.eval_shape(lambda: params), mesh)
print("\nPWS plan (sample):")
for path, spec in list(jax.tree_util.tree_leaves_with_path(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)))[:5]:
    print("  ", jax.tree_util.keystr(path), "->", spec)

# 3. one training step
tokens = jax.random.randint(jax.random.key(1), (2, 32), 3, cfg.vocab_size)
batch = {"tokens": tokens, "labels": tokens}
loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
print(f"\ntrain loss: {float(loss):.4f}")

# 4. prefill + decode
logits, cache = jax.jit(lambda p, b: model.prefill(p, b, 64))(params, batch)
nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
for i in range(4):
    logits, cache = jax.jit(model.decode_step)(params, nxt, jnp.int32(32 + i), cache)
    nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    print(f"decoded token {i}: {nxt[:, 0].tolist()}")
