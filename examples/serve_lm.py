"""Batched serving example: continuous-batch prefill + lockstep greedy
decode with per-request prompts and lengths.

  PYTHONPATH=src python examples/serve_lm.py --arch qwen3-1.7b --batch 4
"""
import argparse
import dataclasses
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.configs import get_smoke_config
from repro.launch.mesh import make_debug_mesh
from repro.launch.serve import Request, Server
from repro.models.base import RunOptions


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = dataclasses.replace(get_smoke_config(args.arch), dtype="float32")
    server = Server(cfg, make_debug_mesh(tp=1), max_len=96,
                    opts=RunOptions(remat="none"))

    rng = np.random.default_rng(0)
    reqs = [
        Request(i, rng.integers(3, cfg.vocab_size, int(rng.integers(4, 24))).astype(np.int32),
                max_new=args.max_new)
        for i in range(args.batch)
    ]
    out = server.run_batch(reqs)
    print(f"served {out['tokens']} tokens in {out['wall_s']:.2f}s "
          f"({out['tok_per_s']:.1f} tok/s, batch={args.batch})")
    for r in reqs:
        print(f"  req {r.uid} (prompt {len(r.prompt):2d} toks) -> {r.out}")


if __name__ == "__main__":
    main()
