"""Kernel-native GQA + quantized KV cache (the decode fast path).

Covers the no-repeat contract end to end: the Pallas kernel consumes K/V at
their *native* head count (the kv ``index_map`` routes each query head's grid
steps into its group's KV row) with parity against the grouped oracle across
``n_rep`` — forward (prefill, static decode, traced decode) and the rep-aware
backward (dk/dv group-summed in the transposed grid's scratch).  The int8 KV
variant (per-(batch, kv-head) scales, in-kernel dequant) matches the
dequantizing oracle tightly and the fp32 ground truth within quantization
error.  Model-layer: the kernel adapter and the blockwise oracle never
materialize a repeated cache (source-level assertion), the fused-QKV variant
is numerically identical to three projections, and the dense family's int8
cache round-trips prefill + decode against the fp32 policy.
"""
import dataclasses
import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import policy, ref, registry
from repro.kernels.flash_attention import flash_attention
from repro.models import common

ATOL = 1e-5

# (h, kvh) pairs giving n_rep in {1, 4, 8}
GQA_SHAPES = [(8, 8), (8, 2), (8, 1)]


def _folded_qkv(b, h, kvh, sq, sk, hd, seed=0):
    """Batch-head-folded operands at the kernel's native-GQA layout:
    q (b*h, sq, hd), k/v (b*kvh, sk, hd)."""
    keys = jax.random.split(jax.random.key(seed), 3)
    return (jax.random.normal(keys[0], (b * h, sq, hd)),
            jax.random.normal(keys[1], (b * kvh, sk, hd)),
            jax.random.normal(keys[2], (b * kvh, sk, hd)))


def _quantize(x):
    """Symmetric per-batch-head int8 twin of the model-layer quantizer,
    for folded (kbh, sk, hd) slabs."""
    scale = jnp.maximum(
        jnp.max(jnp.abs(x), axis=(1, 2)) / 127.0, 1e-8)  # (kbh,)
    q = jnp.clip(jnp.round(x / scale[:, None, None]), -127, 127)
    return q.astype(jnp.int8), scale


# -- native-GQA forward -------------------------------------------------------

@pytest.mark.parametrize("h,kvh", GQA_SHAPES)
def test_gqa_prefill_parity(h, kvh):
    """Self-attention (sq == sk) with native-head K/V: each query head reads
    its group's KV row through the index map; output matches the grouped
    oracle for n_rep 1/4/8."""
    q, k, v = _folded_qkv(2, h, kvh, 128, 128, 32, seed=h * 10 + kvh)
    out = flash_attention(q, k, v, causal=True, n_heads=h,
                          q_block=32, kv_block=32)
    want = ref.flash_attention_ref(q, k, v, causal=True, n_heads=h)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=ATOL)


@pytest.mark.parametrize("h,kvh", GQA_SHAPES)
@pytest.mark.parametrize("pos", [0, 200])
def test_gqa_decode_parity_static(h, kvh, pos):
    """Cached decode (sq=1, static kv_len shrinking the grid) at the native
    KV head count."""
    q, k, v = _folded_qkv(2, h, kvh, 1, 256, 64, seed=pos + h)
    out = flash_attention(q, k, v, causal=True, q_offset=pos, kv_len=pos + 1,
                          q_block=1, kv_block=64, n_heads=h)
    want = ref.flash_attention_ref(q, k, v, causal=True, q_offset=pos,
                                   kv_len=pos + 1, n_heads=h)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=ATOL)


def test_gqa_decode_traced_offset_no_recompile():
    """The serving loop's shape under GQA: traced step position, one
    compilation across every decode position."""
    h, kvh = 8, 2
    q, k, v = _folded_qkv(2, h, kvh, 1, 256, 64)
    calls = []

    @jax.jit
    def step(pos):
        calls.append(1)
        return flash_attention(q, k, v, causal=True, q_offset=pos,
                               kv_len=pos + 1, q_block=1, kv_block=64,
                               n_heads=h)

    for pos in (0, 17, 255):
        out = step(jnp.int32(pos))
        want = ref.flash_attention_ref(q, k, v, causal=True, q_offset=pos,
                                       kv_len=pos + 1, n_heads=h)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=ATOL)
    assert len(calls) == 1


def test_gqa_requires_n_heads():
    q, k, v = _folded_qkv(2, 8, 2, 32, 32, 32)
    with pytest.raises(ValueError, match="n_heads"):
        flash_attention(q, k, v, causal=True, q_block=32, kv_block=32)
    with pytest.raises(ValueError, match="incompatible"):
        flash_attention(q, k, v, causal=True, n_heads=6,
                        q_block=32, kv_block=32)


# -- native-GQA backward ------------------------------------------------------

@pytest.mark.parametrize("h,kvh", GQA_SHAPES)
def test_gqa_vjp_grads_group_summed(h, kvh):
    """dk/dv at the native head count: the transposed grid's (rep, q) inner
    axis accumulates every group member's contribution in scratch; grads
    match the grouped oracle (whose einsum contracts the rep axis)."""
    q, k, v = _folded_qkv(2, h, kvh, 128, 128, 32, seed=7)

    def loss_kernel(q, k, v):
        o = flash_attention(q, k, v, causal=True, n_heads=h,
                            q_block=32, kv_block=32)
        return jnp.sum(o * o)

    def loss_ref(q, k, v):
        o = ref.flash_attention_ref(q, k, v, causal=True, n_heads=h)
        return jnp.sum(o * o)

    got = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    want = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    assert got[1].shape == k.shape and got[2].shape == v.shape
    for g, w, name in zip(got, want, "qkv"):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), atol=1e-4,
                                   err_msg=f"d{name}")


def test_gqa_vjp_with_offsets():
    """Chunked-prefill grads under GQA: offset masking + group sum compose;
    dead cache slots get exactly zero dk/dv."""
    h, kvh = 8, 2
    q, k, v = _folded_qkv(2, h, kvh, 32, 128, 32, seed=11)

    def loss_kernel(q, k, v):
        o = flash_attention(q, k, v, causal=True, q_offset=32, kv_len=64,
                            q_block=32, kv_block=32, n_heads=h)
        return jnp.sum(o * o)

    def loss_ref(q, k, v):
        o = ref.flash_attention_ref(q, k, v, causal=True, q_offset=32,
                                    kv_len=64, n_heads=h)
        return jnp.sum(o * o)

    got = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    want = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for g, w, name in zip(got, want, "qkv"):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), atol=1e-4,
                                   err_msg=f"d{name}")
    assert float(jnp.abs(got[1][:, 64:]).max()) == 0.0
    assert float(jnp.abs(got[2][:, 64:]).max()) == 0.0


# -- int8 KV ------------------------------------------------------------------

@pytest.mark.parametrize("h,kvh", [(8, 8), (8, 2)])
def test_int8_kv_matches_dequant_oracle(h, kvh):
    """The in-kernel dequant computes exactly what the oracle computes on the
    pre-dequantized cache — int8 blocks scaled per (batch, kv-head) at the
    load, MHA and GQA."""
    q, kf, vf = _folded_qkv(2, h, kvh, 1, 256, 64, seed=13)
    k8, ks = _quantize(kf)
    v8, vs = _quantize(vf)
    out = flash_attention(q, k8, v8, causal=True, q_offset=200, kv_len=201,
                          q_block=1, kv_block=64, n_heads=h,
                          k_scale=ks, v_scale=vs)
    want = ref.flash_attention_ref(q, k8, v8, causal=True, q_offset=200,
                                   kv_len=201, n_heads=h,
                                   k_scale=ks, v_scale=vs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=ATOL)


def test_int8_kv_close_to_fp32():
    """Quantization error stays bounded: the int8 cache's attention output
    sits within per-element quantization noise of the fp32 ground truth."""
    h, kvh = 8, 2
    q, kf, vf = _folded_qkv(2, h, kvh, 1, 256, 64, seed=17)
    k8, ks = _quantize(kf)
    v8, vs = _quantize(vf)
    out = flash_attention(q, k8, v8, causal=True, q_offset=255, kv_len=256,
                          q_block=1, kv_block=64, n_heads=h,
                          k_scale=ks, v_scale=vs)
    exact = ref.flash_attention_ref(q, kf, vf, causal=True, q_offset=255,
                                    kv_len=256, n_heads=h)
    err = float(jnp.max(jnp.abs(out - exact)))
    assert err < 0.1, err
    assert err > 0.0  # the quantized path really ran on quantized data


def test_int8_kv_scales_must_pair():
    q, kf, vf = _folded_qkv(2, 8, 2, 1, 64, 32)
    k8, ks = _quantize(kf)
    with pytest.raises(ValueError, match="together"):
        flash_attention(q, k8, vf, causal=True, n_heads=8, k_scale=ks)


# -- model layer --------------------------------------------------------------

def test_kernel_path_never_repeats_kv():
    """The no-copy contract, source-verifiable: neither the kernel adapter
    nor the blockwise oracle's forward calls repeat_kv/jnp.repeat — GQA rides
    index maps (kernel) and grouped einsums (oracle), never a materialized
    cache-sized repeat."""
    for fn in (common._attention_via_kernel, common._blockwise_fwd_inner,
               common.attention_dense):
        src = inspect.getsource(fn)
        assert "repeat_kv(" not in src, fn.__name__
        assert "jnp.repeat" not in src, fn.__name__


def test_model_attention_int8_gqa_decode_parity():
    """common.attention with an int8 cache + scales: the pallas route's
    in-kernel dequant agrees with the jnp route's up-front dequant on a GQA
    decode step."""
    b, h, kvh, hd, sk = 2, 8, 2, 32, 128
    keys = jax.random.split(jax.random.key(5), 3)
    q = jax.random.normal(keys[0], (b, 1, h, hd))
    kf = jax.random.normal(keys[1], (b, sk, kvh, hd))
    vf = jax.random.normal(keys[2], (b, sk, kvh, hd))
    k_scale, v_scale = common.kv_scale(kf), common.kv_scale(vf)
    k8 = common.quantize_kv(kf, k_scale)
    v8 = common.quantize_kv(vf, v_scale)
    pos = jnp.full((1,), 100, jnp.int32)
    kp = jnp.arange(sk, dtype=jnp.int32)
    with policy.apply(impl={"attention": "pallas"}):
        got = common.attention(q, k8, v8, pos, kp, causal=True,
                               q_block=64, kv_block=64,
                               k_scale=k_scale, v_scale=v_scale)
    with policy.apply(impl={"attention": "jnp"}):
        want = common.attention(q, k8, v8, pos, kp, causal=True,
                                q_block=64, kv_block=64,
                                k_scale=k_scale, v_scale=v_scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=ATOL)


def test_qkv_project_fused_parity():
    """The qkv_fused matmul variant: one concatenated projection splits back
    to the same three tensors the unfused path produces."""
    d = 64
    keys = jax.random.split(jax.random.key(9), 4)
    x = jax.random.normal(keys[0], (2, 16, d))
    wq = jax.random.normal(keys[1], (d, 128)) * 0.1
    wk = jax.random.normal(keys[2], (d, 32)) * 0.1
    wv = jax.random.normal(keys[3], (d, 32)) * 0.1
    q0, k0, v0 = common.qkv_project(x, wq, wk, wv)
    with policy.apply(variants={"matmul": {"qkv_fused": True}}):
        q1, k1, v1 = common.qkv_project(x, wq, wk, wv)
    assert q1.shape == q0.shape and k1.shape == k0.shape
    for a, bb, name in ((q0, q1, "q"), (k0, k1, "k"), (v0, v1, "v")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(bb), atol=1e-5,
                                   err_msg=name)


def test_kv_cache_dtype_reads_policy():
    assert common.kv_cache_dtype(jnp.float32) == (jnp.float32, False)
    with policy.apply(variants={"attention": {"kv_dtype": "int8"}}):
        assert common.kv_cache_dtype(jnp.float32) == (jnp.int8, True)
    with policy.apply(variants={"attention": {"kv_dtype": "bf16"}}):
        # unknown names keep the default rather than silently quantizing
        assert common.kv_cache_dtype(jnp.float32) == (jnp.float32, False)


def test_dense_int8_cache_prefill_decode():
    """End to end on the dense family: under the kv_dtype=int8 policy the
    cache is int8 with stored scales, prefill logits match the fp32-cache
    policy exactly (prefill attends the fresh fp k/v), and decode logits
    stay within quantization noise."""
    from repro.configs import get_smoke_config
    from repro.models import build_model
    from repro.models.base import RunOptions

    cfg = get_smoke_config("qwen3-1.7b")
    model = build_model(cfg, RunOptions())
    params = model.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 8), 3, cfg.vocab_size)
    batch = {"tokens": toks}

    logits_fp, cache_fp = model.prefill(params, batch, 32)
    nxt_fp, _ = model.decode_step(params, jnp.argmax(
        logits_fp, -1)[:, None].astype(jnp.int32), jnp.int32(8), cache_fp)

    with policy.apply(variants={"attention": {"kv_dtype": "int8"}}):
        logits_q, cache_q = model.prefill(params, batch, 32)
        assert cache_q.k.dtype == jnp.int8
        assert cache_q.k_scale.shape == (cfg.n_layers, 2, cfg.n_kv_heads)
        nxt_q, cache_q2 = model.decode_step(params, jnp.argmax(
            logits_q, -1)[:, None].astype(jnp.int32), jnp.int32(8), cache_q)
        assert cache_q2.k.dtype == jnp.int8

    # prefill attends the exact fp values while writing the quantized cache
    np.testing.assert_allclose(np.asarray(logits_q), np.asarray(logits_fp),
                               atol=1e-5)
    # decode attends the int8 cache: close, not exact
    np.testing.assert_allclose(np.asarray(nxt_q), np.asarray(nxt_fp),
                               atol=0.5)
