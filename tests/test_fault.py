"""Fault-tolerant serving: deterministic injection, bounded retry,
slot-snapshot recovery, graceful degradation, elastic restart.

The acceptance bar: under a seeded fault plan (decode raises, prefill
delays, poisoned slots) the engine's greedy tokens are IDENTICAL,
request-for-request, to the clean run — recovery must be invisible to
numerics — with ``snapshot_restores >= 1`` confirming the snapshot path
(not whole-residency replay) carried the recovery.  The injector and
retry policy are unit-tested without a model; the engine tests reuse one
module-scoped engine and drive different plans through it.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import save_checkpoint
from repro.configs import get_smoke_config
from repro.launch.engine import Engine, check_lockstep_parity
from repro.launch.mesh import make_debug_mesh
from repro.launch.serve import Request
from repro.models.base import RunOptions
from repro.runtime.fault_tolerance import (
    FaultInjector,
    FaultPolicy,
    InjectedFault,
    LaunchFailedError,
    StragglerMonitor,
    parse_fault_plan,
)


@pytest.fixture(autouse=True)
def _clear_autotune_pin():
    """Server.__init__ pins the autotune mode process-wide; clear it so
    later test modules see the unpinned default again."""
    from repro.kernels import autotune
    yield
    autotune.set_mode(None)


# -- plan grammar + injector (no model) --------------------------------------

def test_fault_plan_parsing():
    specs = parse_fault_plan(
        "decode@12=raise,prefill@3=delay:0.2,slot@2=nan_logits:4")
    assert [(s.kind, s.index, s.action) for s in specs] == [
        ("decode", 12, "raise"), ("prefill", 3, "delay"),
        ("slot", 2, "nan_logits")]
    assert specs[1].arg == pytest.approx(0.2)
    assert specs[2].remaining == 4
    assert parse_fault_plan("") == []
    assert parse_fault_plan("decode@0=raise:3")[0].remaining == 3


@pytest.mark.parametrize("bad", [
    "decode@12",                 # no action
    "decode=raise",              # no index
    "warp@1=raise",              # unknown kind
    "decode@1=explode",          # unknown action
    "decode@1=nan_logits",       # nan_logits targets a slot
    "slot@1=raise",              # raise targets a launch
    "decode@x=raise",            # non-integer index
])
def test_fault_plan_rejects_malformed(bad):
    with pytest.raises(ValueError):
        parse_fault_plan(bad)


def test_injector_from_env(monkeypatch):
    monkeypatch.setenv("REPRO_FAULTS", "decode@5=raise")
    inj = FaultInjector.from_env()
    assert bool(inj) and inj.describe() == "decode@5=raise"
    monkeypatch.delenv("REPRO_FAULTS")
    assert not FaultInjector.from_env()


def test_injector_deterministic_fire_sequence():
    """The same plan fires at the same launches every time: a raise burns
    one count per attempt (so the bounded retry of that launch succeeds),
    and a slot poison counts eligible decode launches down to its n-th."""
    def drive(inj):
        events = []
        for ordinal in range(6):
            try:
                inj.before_launch("decode", ordinal)
            except InjectedFault:
                events.append(("raise", ordinal))
                inj.before_launch("decode", ordinal)  # retry passes
            events.append(("poison", ordinal,
                           tuple(inj.poison_rows([0, 1]))))
        return events

    plan = "decode@2=raise,slot@1=nan_logits:3"
    a, b = drive(FaultInjector(plan)), drive(FaultInjector(plan))
    assert a == b
    assert ("raise", 2) in a
    # the slot poison fires on the 3rd decode launch in which slot 1 decodes
    assert ("poison", 2, (1,)) in a
    assert sum(1 for e in a if e[0] == "poison" and e[2]) == 1


def test_fault_policy_backoff_seeded():
    pol = FaultPolicy(backoff_s=0.01, backoff_mult=2.0, jitter=0.5, seed=7)
    a = [pol.backoff(i, pol.make_rng()) for i in range(3)]
    b = [pol.backoff(i, pol.make_rng()) for i in range(3)]
    assert a == b                              # seeded: reproducible
    for i, d in enumerate(a):                  # jitter bounded above base
        base = 0.01 * 2.0 ** i
        assert base <= d <= base * 1.5


# -- engine fault paths -------------------------------------------------------

def _requests(n, vocab, *, seed=0, max_new=8):
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(3, vocab,
                                    int(rng.integers(4, 20))).astype(np.int32),
                    max_new=int(rng.integers(2, max_new + 1)))
            for i in range(n)]


@pytest.fixture(scope="module")
def mesh():
    return make_debug_mesh(tp=min(2, len(jax.devices())))


@pytest.fixture(scope="module")
def served(mesh):
    """One engine + its clean-run baseline, shared by the fault tests:
    every faulted run must reproduce ``clean_outs`` exactly."""
    cfg = get_smoke_config("qwen3-1.7b")
    engine = Engine(cfg, mesh, max_batch=3, max_len=64, chunk=8,
                    snapshot_every=2, injector=FaultInjector(""),
                    heal_after=4, opts=RunOptions())
    spec = [(r.prompt, r.max_new) for r in _requests(5, cfg.vocab_size)]
    reqs = [Request(i, p, max_new=mn) for i, (p, mn) in enumerate(spec)]
    engine.run(reqs)
    return engine, spec, [list(r.out) for r in reqs]


def _faulted_run(served_fixture, plan, **knobs):
    engine, spec, clean_outs = served_fixture
    engine.injector = FaultInjector(plan)
    for k, v in knobs.items():
        setattr(engine, k, v)
    reqs = [Request(i, p, max_new=mn) for i, (p, mn) in enumerate(spec)]
    out = engine.run(reqs)
    return engine, reqs, out, clean_outs


def test_engine_decode_raise_retries_token_identical(served):
    """An injected decode-launch failure retries under the bounded backoff
    and the run's tokens are request-for-request the clean run's."""
    engine, reqs, out, clean = _faulted_run(served, "decode@1=raise")
    tel = out["telemetry"]
    assert tel["retries"] >= 1 and tel["faults_injected"] == 1
    assert [r.out for r in reqs] == clean
    assert check_lockstep_parity(engine, reqs)


def test_engine_prefill_delay_rides_through(served):
    """An injected prefill straggler slows the launch but changes nothing
    else — no retry, no eviction, identical tokens."""
    engine, reqs, out, clean = _faulted_run(served, "prefill@1=delay:0.05")
    tel = out["telemetry"]
    assert tel["faults_injected"] == 1
    assert tel["retries"] == 0 and tel["slots_poisoned"] == 0
    assert [r.out for r in reqs] == clean


def test_engine_watchdog_flags_injected_straggler(served):
    """The per-launch watchdog: a late injected delay lands far outside
    the rolling wall-time window (fresh monitor, compile times excluded)
    and is flagged; tokens are untouched."""
    served[0].watchdog = StragglerMonitor(window=32, k_sigma=4.0,
                                          min_samples=5)
    engine, reqs, out, clean = _faulted_run(served, "decode@5=delay:0.5")
    assert out["telemetry"]["stragglers"] >= 1
    assert [r.out for r in reqs] == clean


def test_engine_launch_exhaustion_raises(served):
    """Failure model (a): a launch that fails every bounded attempt
    escalates as LaunchFailedError for job-level restart."""
    engine, spec, _ = served
    engine.injector = FaultInjector("decode@0=raise:99")
    old = engine.fault_policy
    engine.fault_policy = FaultPolicy(max_retries=1, backoff_s=1e-4)
    try:
        with pytest.raises(LaunchFailedError) as ei:
            engine.run([Request(0, spec[0][0], max_new=4)])
        assert ei.value.kind == "decode" and ei.value.attempts == 2
    finally:
        engine.fault_policy = old


def test_engine_poisoned_slot_bisected_and_restored(served):
    """Failure model (b): one slot's logits go non-finite; the per-row
    validity vector bisects it, ONLY that request is re-queued, it resumes
    from its last snapshot, and every request's tokens match the clean
    run."""
    engine, reqs, out, clean = _faulted_run(served, "slot@1=nan_logits:3")
    tel = out["telemetry"]
    assert tel["slots_poisoned"] == 1
    assert tel["snapshot_restores"] >= 1       # snapshot, not full replay
    assert tel["matches"] == len(reqs) + 1     # exactly one re-admission
    assert tel["evictions"] == len(reqs)       # completion releases only
    assert [r.out for r in reqs] == clean
    assert check_lockstep_parity(engine, reqs)


def test_engine_degradation_shrinks_and_heals(mesh):
    """Failure model (c): repeated faults inside the window shrink the
    active-slot limit; sustained health probes it back up — and the
    scheduling change never touches tokens."""
    cfg = get_smoke_config("qwen3-1.7b")
    engine = Engine(cfg, mesh, max_batch=3, max_len=64, chunk=8,
                    injector=FaultInjector("decode@2=raise,decode@3=raise"),
                    degrade_after=2, degrade_window=8, heal_after=4,
                    opts=RunOptions())
    rng = np.random.default_rng(1)
    reqs = [Request(i, rng.integers(3, cfg.vocab_size, 8).astype(np.int32),
                    max_new=14) for i in range(3)]
    out = engine.run(reqs)
    tel = out["telemetry"]
    assert tel["degradations"] >= 1
    assert tel["degraded_iters"] >= 1
    deg = engine.stats()["degradation"]
    assert deg["active_limit"] == deg["max_batch"]   # healed by run end
    assert not deg["degraded"]
    assert check_lockstep_parity(engine, reqs)


def test_engine_fault_storm_acceptance(served):
    """The acceptance criterion: >= 1 decode raise + >= 1 prefill delay +
    >= 1 poisoned slot in one seeded plan; greedy tokens request-for-request
    identical to the clean run with snapshot_restores >= 1."""
    engine, reqs, out, clean = _faulted_run(
        served, "decode@1=raise,prefill@1=delay:0.05,slot@0=nan_logits:4")
    tel = out["telemetry"]
    assert tel["faults_injected"] == 3
    assert tel["retries"] >= 1
    assert tel["slots_poisoned"] == 1
    assert tel["snapshot_restores"] >= 1
    assert [r.out for r in reqs] == clean
    assert check_lockstep_parity(engine, reqs)


# -- elastic restart ----------------------------------------------------------

def test_engine_elastic_restart_identical_logits(mesh, tmp_path):
    """Serving restart on a re-planned (shrunken when devices allow) mesh:
    params restore through elastic.serving_restore and the restarted
    replica's logits — and greedy tokens — are identical to the source
    replica's.  Params are perturbed before saving so the assertion cannot
    pass on a fresh init."""
    cfg = get_smoke_config("qwen3-1.7b")
    src = Engine(cfg, mesh, max_batch=2, max_len=64, chunk=8,
                 opts=RunOptions())
    src.params = jax.tree.map(lambda x: x * 1.5, src.params)
    save_checkpoint(tmp_path, 3, {"params": src.params},
                    mesh_shape=dict(mesh.shape))

    small = make_debug_mesh(1, tp=1)  # a strict shrink when >1 device
    restarted = Engine.restart(cfg, small, tmp_path, max_batch=2,
                               max_len=64, chunk=8, opts=RunOptions())

    prompt = np.arange(3, 11, dtype=np.int32)
    batch = {"tokens": jnp.asarray(prompt)[None]}
    from repro.core.sharding_hints import axis_rules
    with mesh, axis_rules(src.rules, mesh):
        la, _ = src._prefill(src.params, batch)
    with small, axis_rules(restarted.rules, small):
        lb, _ = restarted._prefill(restarted.params, batch)
    np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))

    a = [Request(0, prompt, max_new=6)]
    b = [Request(0, prompt, max_new=6)]
    src.run(a)
    restarted.run(b)
    assert a[0].out == b[0].out
