"""BI (Morton) layout, gapping, in-order layout — unit + property tests."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip cleanly when absent
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import layouts


@given(st.integers(0, 2**15 - 1), st.integers(0, 2**15 - 1))
def test_bi_index_roundtrip(r, c):
    z = layouts.bi_index(np.asarray([r]), np.asarray([c]))
    rr, cc = layouts.bi_coords(z)
    assert int(rr[0]) == r and int(cc[0]) == c


@pytest.mark.parametrize("n", [2, 4, 8, 32])
def test_rm_bi_perms_inverse(n):
    p1 = layouts.rm_to_bi_perm(n)
    p2 = layouts.bi_to_rm_perm(n)
    m = np.arange(n * n)
    assert np.array_equal(m.reshape(-1)[p1][p2], m)


def test_bi_quadrants_are_contiguous():
    """The defining property: each quadrant of the matrix is one contiguous
    quarter of the BI index space (recursively)."""
    n = 16
    z = np.arange(n * n)
    r, c = layouts.bi_coords(z)
    # first quarter of z-space = top-left quadrant
    q0 = slice(0, n * n // 4)
    assert r[q0].max() < n // 2 and c[q0].max() < n // 2
    q3 = slice(3 * n * n // 4, n * n)
    assert r[q3].min() >= n // 2 and c[q3].min() >= n // 2


def test_gap_for_constant_expansion():
    """sum over r=2^i of gap/r = O(1): total gapped size <= c * n."""
    for n in [64, 256, 1024, 4096]:
        assert layouts.gapped_size(n) <= 3 * n * n


@pytest.mark.parametrize("m,n", [(64, 4096), (16, 1024), (1024, 1024)])
def test_gapped_list_positions_disjoint_and_spread(m, n):
    pos = layouts.gapped_list_positions(m, n)
    assert len(np.unique(pos)) == m
    assert pos.max() < max(n, m)


def test_inorder_positions_separation():
    """Nodes whose subtrees exceed B leaves are >= B apart in the in-order
    layout (the paper's zero-block-sharing argument for the up-pass)."""
    n = 256
    pos = layouts.inorder_positions(n)
    B = 16
    big = [(lv, i) for (lv, i) in pos if 2**lv >= B]
    vals = sorted(pos[k] for k in big)
    diffs = np.diff(vals)
    assert (diffs >= B - 1).all()
