"""Mamba-2 SSD: chunked algorithm vs naive recurrence oracle; decode-step
consistency; the BP two-pass structure (chunk-size invariance)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.ssm import ssd_chunked, ssd_decode_step


def naive_ssd(x, a, B, C):
    """Step-by-step oracle: s_t = exp(a_t) s_{t-1} + x_t B_t^T; y_t = C_t s_t."""
    b, l, h, p = x.shape
    n = B.shape[-1]
    s = np.zeros((b, h, p, n), np.float64)
    ys = np.zeros((b, l, h, p), np.float64)
    xa, aa, Ba, Ca = map(np.asarray, (x, a, B, C))
    for t in range(l):
        decay = np.exp(aa[:, t])[:, :, None, None]
        s = decay * s + np.einsum("bhp,bn->bhpn", xa[:, t], Ba[:, t])
        ys[:, t] = np.einsum("bhpn,bn->bhp", s, Ca[:, t])
    return ys, s


def rand_inputs(b=2, l=32, h=3, p=4, n=8, seed=0):
    ks = jax.random.split(jax.random.key(seed), 4)
    x = jax.random.normal(ks[0], (b, l, h, p), jnp.float32)
    a = -jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))  # negative log-decay
    B = jax.random.normal(ks[2], (b, l, n), jnp.float32)
    C = jax.random.normal(ks[3], (b, l, n), jnp.float32)
    return x, a, B, C


@pytest.mark.parametrize("chunk", [4, 8, 16, 32])
def test_ssd_chunked_matches_naive(chunk):
    x, a, B, C = rand_inputs()
    y, s = ssd_chunked(x, a, B, C, chunk=chunk)
    y_ref, s_ref = naive_ssd(x, a, B, C)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s), s_ref, rtol=2e-4, atol=2e-4)


def test_chunk_size_invariance():
    """The BP balance property: the result is independent of leaf size."""
    x, a, B, C = rand_inputs(l=64)
    y1, s1 = ssd_chunked(x, a, B, C, chunk=8)
    y2, s2 = ssd_chunked(x, a, B, C, chunk=32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4, atol=1e-4)


def test_decode_step_continues_chunked_state():
    x, a, B, C = rand_inputs(l=16)
    _, s = ssd_chunked(x, a, B, C, chunk=8)
    x1, a1, B1, C1 = rand_inputs(l=1, seed=9)
    y, s2 = ssd_decode_step(x1[:, 0], a1[:, 0], B1[:, 0], C1[:, 0], s)
    # oracle: run 17 steps
    xa = jnp.concatenate([x, x1], 1)
    aa = jnp.concatenate([a, a1], 1)
    Ba = jnp.concatenate([B, B1], 1)
    Ca = jnp.concatenate([C, C1], 1)
    y_ref, s_ref = naive_ssd(xa, aa, Ba, Ca)
    np.testing.assert_allclose(np.asarray(y), y_ref[:, -1], rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s2), s_ref, rtol=2e-4, atol=2e-4)


def test_initial_state_threading():
    """ssd(x[0:l1]) then ssd(x[l1:], init=state) == ssd(x) — the HBP
    sequencing property used by prefill."""
    x, a, B, C = rand_inputs(l=32)
    y_all, s_all = ssd_chunked(x, a, B, C, chunk=8)
    y1, s1 = ssd_chunked(x[:, :16], a[:, :16], B[:, :16], C[:, :16], chunk=8)
    y2, s2 = ssd_chunked(x[:, 16:], a[:, 16:], B[:, 16:], C[:, 16:], chunk=8,
                         initial_state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_all), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_all), rtol=2e-4, atol=2e-4)
