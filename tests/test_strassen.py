"""Strassen-schedule matmul: kernel numerics vs the oracle across
dtypes/sizes (incl. shapes that must route classical), planner backend
selection at the costmodel crossover (with a hypothesis monotonicity
property), v3 backend-flagged autotune keys (search/replay round-trip,
variant candidates, cross-shape interpolation), ragged hbp_matmul
overrides, and model-matmul routing parity (greedy decode + one train
step, impl="pallas" vs impl="jnp")."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import costmodel
from repro.kernels import autotune, planner, ref, registry
from repro.kernels.strassen_matmul import matmul as backend_matmul
from repro.kernels.strassen_matmul import strassen_matmul

DP = planner.DeviceParams(platform="cpu", kind="test", fast_bytes=8 * 2**20,
                          line_bytes=64)


@pytest.fixture
def tune_dir(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_DIR", str(tmp_path))
    autotune.clear_cache()
    yield tmp_path
    autotune.clear_cache()


def _mats(n, dtype, seed=0):
    a = jax.random.normal(jax.random.key(seed), (n, n), jnp.float32)
    b = jax.random.normal(jax.random.key(seed + 1), (n, n), jnp.float32)
    return a.astype(dtype), b.astype(dtype)


def _tol(dtype):
    # Strassen's combination tree amplifies rounding: operands reach 2x
    # magnitude per level and the output combines cancel.  bf16 on N(0,1)
    # inputs at n<=512 stays within a few ulps of the ~sqrt(n) dot scale.
    if dtype == jnp.bfloat16:
        return dict(rtol=8e-2, atol=1.5)
    return dict(rtol=2e-3, atol=2e-3)


# -- kernel numerics ----------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,cutoff", [(128, 32), (256, 64), (192, 48)])
def test_strassen_matches_oracle(n, cutoff, dtype):
    """Multi-level recursion (incl. a non-pow2 even edge, 192 -> 96 -> 48)
    against the f32 oracle."""
    a, b = _mats(n, dtype, seed=n)
    out = strassen_matmul(a, b, cutoff=cutoff)
    assert out.dtype == dtype
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_strassen_matches_textbook_recursion():
    """The signed ``_STRASSEN_LHS/RHS/OUT`` combination (index structure
    shared with the core simulator) is the same function as the textbook
    recursion in ``core.algorithms_jax``."""
    from repro.core.algorithms_jax import strassen as strassen_jnp

    a, b = _mats(128, jnp.float32)
    got = strassen_matmul(a, b, cutoff=32)
    want = strassen_jnp(a, b, leaf=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_strassen_ineligible_shape_falls_through():
    """Odd edges above the cutoff stop the recursion (big classical leaves),
    and a flat-out odd size falls straight to the tiled kernel / oracle."""
    for n in (130, 65):
        a, b = _mats(n, jnp.float32, seed=n)
        out = strassen_matmul(a, b, cutoff=32)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref.matmul_ref(a, b)),
                                   rtol=2e-3, atol=2e-3)


def test_backend_matmul_dispatch_and_vjp():
    """The registry's matmul entry: explicit backend override, planner
    default, and gradients through both backends match the jnp grads."""
    a, b = _mats(128, jnp.float32)
    for backend in ("classical", "strassen"):
        got = registry.dispatch("matmul", a, b, impl="pallas",
                                backend=backend, cutoff=32)
        np.testing.assert_allclose(np.asarray(got), np.asarray(a @ b),
                                   rtol=2e-3, atol=2e-3)
        da, db = jax.grad(
            lambda x, y: registry.dispatch(
                "matmul", x, y, impl="pallas", backend=backend,
                cutoff=32).sum(), argnums=(0, 1))(a, b)
        np.testing.assert_allclose(np.asarray(da), np.asarray(b.sum(1)[None, :] * jnp.ones_like(a)),
                                   rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(np.asarray(db), np.asarray(a.sum(0)[:, None] * jnp.ones_like(b)),
                                   rtol=2e-3, atol=2e-3)
    with pytest.raises(ValueError, match="unknown matmul backend"):
        backend_matmul(a, b, backend="winograd")


# -- planner backend selection ------------------------------------------------

def test_plan_matmul_backend_crossover():
    """Strassen only above the modeled crossover, only for square
    pow2-friendly edges, only for fp32/bf16."""
    cut = planner.strassen_cutoff(jnp.float32, DP)
    assert cut == costmodel.strassen_crossover_edge(
        DP.fast_bytes // 3 // 4, DP.line_bytes // 4)
    below = planner.plan_matmul(cut, cut, cut, jnp.float32, DP)
    above = planner.plan_matmul(2 * cut, 2 * cut, 2 * cut, jnp.float32, DP)
    assert below["backend"] == "classical" and "cutoff" not in below
    assert above["backend"] == "strassen" and above["cutoff"] == cut
    # non-square / low-precision / odd-above-cutoff shapes stay classical
    assert planner.plan_matmul(2 * cut, cut, 2 * cut, jnp.float32,
                               DP)["backend"] == "classical"
    assert planner.plan_matmul(2 * cut, 2 * cut, 2 * cut, jnp.int8,
                               DP)["backend"] == "classical"
    odd = 2 * (cut + 1)  # halves once to an odd edge just above the cutoff
    assert odd % 2 == 0 and (odd // 2) % 2 and odd // 2 > cut
    assert planner.plan_matmul(odd, odd, odd, jnp.float32,
                               DP)["backend"] == "classical"


def test_plan_matmul_backend_monotone_in_n():
    """Hypothesis property: over square power-of-two n, once the planner
    picks Strassen it keeps picking it for every larger n (at any queried
    fast-memory size and eligible dtype)."""
    pytest.importorskip("hypothesis")  # optional dep: skip cleanly when absent
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @given(dtype=st.sampled_from(["float32", "bfloat16"]),
           mem_pow=st.integers(16, 28),
           line=st.sampled_from([64, 128, 512]))
    @settings(max_examples=40, deadline=None)
    def check(dtype, mem_pow, line):
        dp = planner.DeviceParams("cpu", "prop", 2 ** mem_pow, line)
        picks = [planner.plan_matmul(n, n, n, dtype, dp)["backend"]
                 for n in (1 << j for j in range(5, 15))]
        first = picks.index("strassen") if "strassen" in picks else len(picks)
        assert all(p == "classical" for p in picks[:first])
        assert all(p == "strassen" for p in picks[first:])

    check()


# -- autotune: v3 keys, variants, interpolation -------------------------------

def test_entry_key_carries_matmul_backend_flag():
    a = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    key = autotune.entry_key("matmul", a, a)
    assert "backend=" in key
    # an explicit kwarg overrides the planner-derived flag
    forced = autotune.entry_key("matmul", a, a, kwargs={"backend": "strassen"})
    assert "backend=strassen" in forced


def test_matmul_candidates_cover_backend_and_morton():
    a = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    cands = autotune.candidates("matmul", a, a, dp=DP)
    assert cands[0] == dict(registry.get("matmul").plan(a, a))
    assert any(p.get("morton") is False for p in cands)
    assert any(p.get("backend") == "strassen" for p in cands)
    # transpose tunes its morton flag too
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    assert any(p.get("morton") is False
               for p in autotune.candidates("transpose", x, dp=DP))


def test_search_replay_roundtrip_with_backend_keys(tune_dir, monkeypatch):
    """Shrink the queried fast memory so a 256-edge matmul crosses into the
    Strassen regime, search it, and replay the (backend-flagged) winner
    through dispatch."""
    monkeypatch.setenv("REPRO_FAST_BYTES", str(1 << 18))
    planner.clear_device_params_cache()
    try:
        plan = planner.plan_matmul(256, 256, 256, jnp.float32)
        assert plan["backend"] == "strassen"
        a, b = _mats(256, jnp.float32)
        entry = autotune.search("matmul", a, b, iters=1, max_candidates=4)
        assert entry["plan"].get("backend") in ("classical", "strassen")
        key = autotune.entry_key("matmul", a, b)
        assert "backend=strassen" in key
        autotune.clear_cache()  # force the JSON round-trip
        assert autotune.lookup("matmul", a, b) == entry["plan"]
        with autotune.mode_scope("replay"):
            got = registry.dispatch("matmul", a, b, impl="pallas")
        np.testing.assert_allclose(np.asarray(got), np.asarray(a @ b),
                                   rtol=2e-3, atol=2e-3)
    finally:
        planner.clear_device_params_cache()


def test_dispatch_keys_forced_variant_overrides(tune_dir, monkeypatch):
    """A call that pins ``backend=`` must key the overlay lookup on the
    forced variant, not the planner's own choice — otherwise a
    forced-classical run replays tiles tuned for the Strassen entry."""
    captured = {}
    orig = autotune.overlay

    def spy(op, args, *, search_kwargs=None):
        captured.update(search_kwargs or {})
        return orig(op, args, search_kwargs=search_kwargs)

    monkeypatch.setattr(autotune, "overlay", spy)
    a, b = _mats(64, jnp.float32)
    with autotune.mode_scope("replay"):
        registry.dispatch("matmul", a, b, impl="pallas", backend="classical")
    assert captured.get("backend") == "classical"


def test_overlay_interpolates_nearest_shape_class(tune_dir):
    """A table miss borrows the nearest recorded shape_class for the same
    (op, dtype, flags) instead of going cold; exact hits still win and
    foreign dtypes are never borrowed."""
    x512 = jax.random.normal(jax.random.key(0), (8, 512))
    x2048 = jax.random.normal(jax.random.key(1), (8, 2048))
    x1024 = jax.random.normal(jax.random.key(2), (8, 1024))
    table = autotune.load_table()
    table[autotune.entry_key("scan", x512)] = {"plan": {"block": 64}, "us": 1.0}
    table[autotune.entry_key("scan", x2048)] = {"plan": {"block": 512}, "us": 1.0}
    autotune.save_table()
    with autotune.mode_scope("replay"):
        # 1024 misses; 512 and 2048 are equidistant — deterministic pick,
        # snapped to the actual axis
        got = autotune.overlay("scan", (x1024,))
        assert got in ({"block": 64}, {"block": 512})
        # exact entry beats interpolation
        table = autotune.load_table()
        table[autotune.entry_key("scan", x1024)] = {"plan": {"block": 128},
                                                    "us": 1.0}
        autotune.save_table()
        assert autotune.overlay("scan", (x1024,)) == {"block": 128}
        # dtype mismatch: nothing to borrow
        xb = x1024.astype(jnp.bfloat16)
        assert autotune.overlay("scan", (xb,)) == {}


# -- ragged hbp_matmul overrides ----------------------------------------------

def test_hbp_matmul_ragged_override_snaps():
    """A non-divisor tile override snaps to the largest divisor instead of
    tripping the old ``m % bm == 0`` assert."""
    a, b = _mats(96, jnp.float32)
    got = registry.dispatch("matmul", a, b, impl="pallas",
                            bm=64, bn=64, bk=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(a @ b),
                               rtol=1e-3, atol=1e-3)


def test_hbp_matmul_degenerate_snap_falls_back():
    """Prime-ish dims whose best divisor is sub-sublane take the jnp oracle
    instead of a catastrophically fine grid."""
    a, b = _mats(31, jnp.float32)
    got = registry.dispatch("matmul", a, b, impl="pallas",
                            bm=16, bn=16, bk=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(a @ b),
                               rtol=1e-4, atol=1e-4)


# -- model routing parity -----------------------------------------------------

def _smoke_models():
    from repro.models import build_model
    from repro.models.base import RunOptions
    from repro.configs import get_smoke_config

    cfg = dataclasses.replace(get_smoke_config("qwen3-1.7b"), dtype="float32")
    mj = build_model(cfg, RunOptions(remat="none", matmul_impl="jnp"))
    mp = build_model(cfg, RunOptions(remat="none", matmul_impl="pallas"))
    return cfg, mj, mp


def test_model_matmul_impl_greedy_decode_parity():
    """Greedy decode tokens are identical with model matmuls routed through
    the kernel registry vs the jnp einsums (PR 3's end-to-end parity bar)."""
    cfg, mj, mp = _smoke_models()
    params = mj.init(jax.random.key(0))
    prompt = jax.random.randint(jax.random.key(1), (2, 6), 3, cfg.vocab_size)
    max_len = 24

    def greedy(model, steps=4):
        logits, cache = jax.jit(
            lambda p, t: model.prefill(p, t, max_len))(params, {"tokens": prompt})
        dec = jax.jit(model.decode_step)
        cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out = []
        for i in range(steps):
            out.append(np.asarray(cur[:, 0]))
            logits, cache = dec(params, cur, jnp.int32(6 + i), cache)
            cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        return np.stack(out)

    np.testing.assert_array_equal(greedy(mj), greedy(mp))


def test_model_matmul_impl_train_step_parity():
    """One train step (loss + grads) through the kernel route matches the
    jnp route — the matmul custom VJP under scan + chunked-xent remat."""
    cfg, mj, mp = _smoke_models()
    params = mj.init(jax.random.key(0))
    batch = {
        "tokens": jax.random.randint(jax.random.key(2), (2, 32), 3, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.key(3), (2, 32), 0, cfg.vocab_size),
    }
    lj, gj = jax.value_and_grad(mj.loss)(params, batch)
    lp, gp = jax.value_and_grad(mp.loss)(params, batch)
    np.testing.assert_allclose(float(lj), float(lp), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(gj), jax.tree.leaves(gp)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=1e-5)
