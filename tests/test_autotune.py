"""Measured autotune layer: candidate envelope/divisibility invariants,
table JSON round-trips, replay semantics (cold cache = no-op, corrupt table
= ignored), dispatch integration, and the planner satellites (memoized
device_params, memory_stats query, dropped-override warning)."""
import json
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune, planner, registry


@pytest.fixture
def tune_dir(tmp_path, monkeypatch):
    """Redirect the tile table to a fresh directory and drop caches."""
    monkeypatch.setenv("REPRO_TUNE_DIR", str(tmp_path))
    autotune.clear_cache()
    yield tmp_path
    autotune.clear_cache()


def _sds_case(name, shapes, dtype):
    """ShapeDtypeStruct args — candidates/plans never need real buffers."""
    if name == "fft":
        dtype = jnp.complex64
    return tuple(jax.ShapeDtypeStruct(s, dtype) for s in shapes)


_CASES = {
    "scan": [(8, 8192)],
    "matmul": [(512, 384), (384, 768)],
    "transpose": [(512, 256)],
    "attention": [(4, 384, 64), (4, 384, 64), (4, 384, 64)],
    "fft": [(4, 1024)],
}


# -- candidate generation: the property the tuner must never break -----------

@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("name", sorted(_CASES))
def test_candidates_satisfy_divisibility_and_envelope(name, dtype):
    args = _sds_case(name, _CASES[name], jnp.dtype(dtype))
    dp = planner.DeviceParams("cpu", "test", 8 * 2**20, 64)
    info = autotune._TUNE[name]
    dims = info.dims(*args)
    cands = autotune.candidates(name, *args, dp=dp)
    assert cands, name
    assert cands[0] == dict(registry.get(name).plan(*args))  # analytic first
    seen = set()
    for plan in cands:
        key = tuple(sorted((k, str(v)) for k, v in plan.items()))
        assert key not in seen  # no duplicate timings
        seen.add(key)
        for k, v in plan.items():
            if k not in dims:  # variant knobs (backend/cutoff/morton)
                assert k in info.variant_keys, (name, k)
                continue
            assert dims[k] % v == 0, (name, plan)
        assert info.working_set(plan, *args) <= dp.fast_bytes, (name, plan)


def test_candidates_property_random_shapes():
    """Hypothesis sweep: every candidate for every op divides its axes and
    fits the queried fast memory, across random shapes/dtypes/memory sizes."""
    pytest.importorskip("hypothesis")  # optional dep: skip cleanly when absent
    from hypothesis import given, settings
    from hypothesis import strategies as st

    dims = st.integers(1, 10).map(lambda p: 2 ** p)
    odd_dims = st.integers(1, 1024)

    @given(name=st.sampled_from(sorted(autotune._TUNE)),
           a=dims, b=odd_dims, c=dims,
           dtype=st.sampled_from(["float32", "bfloat16", "int8"]),
           mem_pow=st.integers(16, 26))
    @settings(max_examples=60, deadline=None)
    def check(name, a, b, c, dtype, mem_pow):
        if name == "scan":
            shapes, dt = [(4, b)], jnp.dtype(dtype)
        elif name == "matmul":
            shapes, dt = [(a, b), (b, c)], jnp.dtype(dtype)
        elif name == "transpose":
            shapes, dt = [(a, b)], jnp.dtype(dtype)
        elif name == "attention":
            shapes, dt = [(2, a, 64)] * 3, jnp.dtype(dtype)
        else:  # fft: power-of-two length
            shapes, dt = [(2, a)], jnp.complex64
        args = tuple(jax.ShapeDtypeStruct(s, dt) for s in shapes)
        dp = planner.DeviceParams("cpu", "prop", 2 ** mem_pow, 64)
        info = autotune._TUNE[name]
        axis = info.dims(*args)
        for plan in autotune.candidates(name, *args, dp=dp):
            for k, v in plan.items():
                if k not in axis:  # variant knobs (backend/cutoff/morton)
                    assert k in info.variant_keys
                    continue
                assert axis[k] % v == 0
            assert info.working_set(plan, *args) <= dp.fast_bytes

    check()


# -- shape classes and snapping ----------------------------------------------

def test_shape_class_buckets_to_pow2():
    a = jax.ShapeDtypeStruct((384, 500), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    assert autotune.shape_class(a) == autotune.shape_class(b) == "512x512"
    assert autotune.entry_key("transpose", a).startswith("transpose|512x512|")


def test_entry_key_carries_semantic_flags():
    """Causal vs windowed vs decode attention must not share one table entry
    (same shape class, different measured optimum)."""
    q = jax.ShapeDtypeStruct((4, 512, 64), jnp.float32)
    kv = jax.ShapeDtypeStruct((4, 512, 64), jnp.float32)
    k_causal = autotune.entry_key("attention", q, kv, kv,
                                  kwargs={"causal": True, "window": 0})
    k_plain = autotune.entry_key("attention", q, kv, kv,
                                 kwargs={"causal": False, "window": 0})
    k_win = autotune.entry_key("attention", q, kv, kv,
                               kwargs={"causal": True, "window": 128})
    assert len({k_causal, k_plain, k_win}) == 3
    assert "causal=True" in k_causal and "window=128" in k_win
    # decode (sq != sk) is a derived flag: same kwargs, different key
    qd = jax.ShapeDtypeStruct((4, 1, 64), jnp.float32)
    k_dec = autotune.entry_key("attention", qd, kv, kv,
                               kwargs={"causal": True, "window": 0})
    assert "decode=True" in k_dec and "decode=False" in k_causal
    # omitted kwargs normalize to the kernel defaults: one key per config
    # regardless of calling convention
    assert autotune.entry_key("attention", q, kv, kv) == k_causal
    assert autotune.entry_key("attention", q, kv, kv,
                              kwargs={"causal": None}) == k_causal
    # flag-less ops keep the bare three-field key (no format churn)
    x = jax.ShapeDtypeStruct((4, 256), jnp.float32)
    assert autotune.entry_key("scan", x) == "scan|4x256|float32"


def test_snap_plan_restores_divisibility_across_class():
    # a plan recorded for n=512 replays on the same-class n=384 input
    x384 = jax.ShapeDtypeStruct((4, 384), jnp.float32)
    snapped = autotune.snap_plan("scan", (x384,), {"block": 512})
    assert 384 % snapped["block"] == 0 and snapped["block"] <= 512


# -- table persistence --------------------------------------------------------

def test_search_persists_and_roundtrips(tune_dir):
    x = jax.random.normal(jax.random.key(0), (2, 256))
    entry = autotune.search("scan", x, iters=2, max_candidates=4)
    # best-of includes the analytic point, so tuned can never measure worse
    assert entry["us"] <= entry["analytic_us"]
    path = autotune.table_path()
    assert path.exists()
    # round-trip through JSON: a cold process (cache cleared) sees the entry
    autotune.clear_cache()
    plan = autotune.lookup("scan", x)
    assert plan == entry["plan"]
    raw = json.loads(path.read_text())
    assert raw["version"] == autotune._TABLE_VERSION
    assert raw["jax_version"] == jax.__version__  # stamped on write
    assert len(raw["entries"]) == 1


def test_replay_cold_cache_is_noop(tune_dir):
    x = jax.random.normal(jax.random.key(0), (2, 256))
    with autotune.mode_scope("replay"):
        assert autotune.overlay("scan", (x,)) == {}
        got = registry.dispatch("scan", x, impl="pallas")
    want = registry.dispatch("scan", x, impl="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    assert not list(tune_dir.iterdir())  # replay never writes


@pytest.mark.parametrize("payload", [
    "not json at all {{{",
    '{"version": 99, "entries": {}}',
    '[1, 2, 3]',
    # pre-flag key format (table version 1): ignored wholesale, not migrated
    '{"version": 1, "entries": {"scan|4x256|float32": {"plan": {"block": "x"}}}}',
])
def test_corrupt_or_foreign_tables_are_ignored(tune_dir, payload):
    path = autotune.table_path()
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(payload)
    autotune.clear_cache()
    assert autotune.load_table() == {}  # never raises
    x = jax.random.normal(jax.random.key(0), (2, 256))
    with autotune.mode_scope("replay"):
        got = registry.dispatch("scan", x, impl="pallas")  # still runs
    np.testing.assert_allclose(
        np.asarray(got),
        np.asarray(registry.dispatch("scan", x, impl="ref")),
        rtol=1e-4, atol=1e-4)


def test_stale_jax_stamp_is_cold_cache(tune_dir):
    """A table tuned under another jaxlib replays nothing: tuned timings do
    not survive toolchain upgrades, so the stamp mismatch means cold."""
    x = jax.random.normal(jax.random.key(0), (2, 256))
    table = autotune.load_table()
    table[autotune.entry_key("scan", x)] = {"plan": {"block": 64}, "us": 1.0}
    path = autotune.save_table()
    raw = json.loads(path.read_text())
    raw["jax_version"] = "0.0.0-somebody-else"
    path.write_text(json.dumps(raw))
    autotune.clear_cache()
    assert autotune.load_table() == {}
    with autotune.mode_scope("replay"):
        assert autotune.overlay("scan", (x,)) == {}  # degrades, never replays


def test_dispatch_replays_tuned_plan(tune_dir):
    """A persisted (non-analytic) plan actually reaches the kernel, and
    explicit overrides still win over it."""
    x = jax.random.normal(jax.random.key(0), (2, 256))
    table = autotune.load_table()
    table[autotune.entry_key("scan", x)] = {"plan": {"block": 64}, "us": 1.0}
    autotune.save_table()
    with autotune.mode_scope("replay"):
        assert autotune.overlay("scan", (x,)) == {"block": 64}
        got = registry.dispatch("scan", x, impl="pallas")
        np.testing.assert_allclose(
            np.asarray(got),
            np.asarray(registry.dispatch("scan", x, impl="ref")),
            rtol=1e-4, atol=1e-4)
        # an explicit non-divisor override must still reach the kernel
        # (and trip its divisibility assert) — the tuned plan does not mask it
        with pytest.raises(AssertionError):
            registry.dispatch("scan", x, impl="pallas", block=60)


def test_search_mode_fills_table_from_dispatch(tune_dir):
    x = jax.random.normal(jax.random.key(0), (2, 128))
    with autotune.mode_scope("search"):
        registry.dispatch("scan", x, impl="pallas")
    assert autotune.lookup("scan", x) is not None  # miss triggered a search
    # under jit the args are tracers: search must degrade to replay, not time
    y = jax.random.normal(jax.random.key(1), (2, 64))
    with autotune.mode_scope("search"):
        jax.jit(lambda t: registry.dispatch("scan", t, impl="pallas"))(y)
    assert autotune.lookup("scan", y) is None


# -- mode knob ----------------------------------------------------------------

def test_mode_resolution(monkeypatch):
    # a launcher earlier in the test run may have pinned the process-wide
    # override (startup is documented to do so); isolate this test from it
    monkeypatch.setattr(autotune, "_mode_override", None)
    monkeypatch.delenv("REPRO_AUTOTUNE", raising=False)
    assert autotune.mode() == "off"           # bare dispatch default
    assert autotune.resolve_mode() == "replay"  # launcher default
    assert autotune.resolve_mode("search") == "search"
    monkeypatch.setenv("REPRO_AUTOTUNE", "search")
    assert autotune.mode() == "search"
    assert autotune.resolve_mode() == "search"
    with pytest.raises(ValueError, match="unknown autotune mode"):
        autotune.resolve_mode("sideways")
    with pytest.raises(ValueError, match="unknown autotune mode"):
        autotune.set_mode("sideways")
    monkeypatch.setenv("REPRO_AUTOTUNE", "bogus")
    assert autotune.mode() == "off"  # bad env degrades, never raises


def test_run_options_resolution(monkeypatch):
    from repro.models.base import RunOptions

    monkeypatch.delenv("REPRO_AUTOTUNE", raising=False)
    opts = planner.resolve_run_options(RunOptions())
    assert opts.autotune == "replay"
    assert planner.resolve_run_options(opts) is opts  # idempotent
    pinned = planner.resolve_run_options(RunOptions(autotune="off"))
    assert pinned.autotune == "off"
    monkeypatch.setenv("REPRO_AUTOTUNE", "search")
    assert planner.resolve_run_options(RunOptions()).autotune == "search"


# -- planner satellites -------------------------------------------------------

def test_device_params_memoized_with_clear_hook(monkeypatch):
    monkeypatch.delenv("REPRO_FAST_BYTES", raising=False)
    planner.clear_device_params_cache()
    dp1 = planner.device_params()
    assert planner.device_params() is dp1  # memoized object identity
    # REPRO_FAST_BYTES participates in the key: no stale hit after a flip
    monkeypatch.setenv("REPRO_FAST_BYTES", str(1 << 20))
    assert planner.device_params().fast_bytes == 1 << 20
    monkeypatch.delenv("REPRO_FAST_BYTES", raising=False)
    assert planner.device_params() is dp1
    planner.clear_device_params_cache()
    dp2 = planner.device_params()
    assert dp2 == dp1 and dp2 is not dp1  # hook really dropped the cache


class _FakeDev:
    platform = "cpu"
    device_kind = "fake-l2"

    def __init__(self, stats):
        self._stats = stats

    def memory_stats(self):
        if isinstance(self._stats, Exception):
            raise self._stats
        return self._stats


@pytest.mark.parametrize("stats,want", [
    ({"vmem_size_bytes": 4 * 2**20}, 4 * 2**20),          # explicit key wins
    ({"bytes_limit": 2 * 2**20}, 2 * 2**20),              # smaller than default
    ({"bytes_limit": 64 * 2**30}, 8 * 2**20),             # HBM-sized: ignored
    (None, 8 * 2**20),                                    # backend says nothing
    (RuntimeError("unimplemented"), 8 * 2**20),           # backend raises
])
def test_device_params_queries_memory_stats(monkeypatch, stats, want):
    monkeypatch.delenv("REPRO_FAST_BYTES", raising=False)
    dp = planner.device_params(_FakeDev(stats))
    assert dp.fast_bytes == want
    assert dp.kind == "fake-l2"


def test_ref_path_warns_once_on_dropped_tile_overrides(monkeypatch):
    monkeypatch.setattr(registry, "_WARNED_DROPPED", set())
    x = jax.random.normal(jax.random.key(0), (2, 256))
    with pytest.warns(UserWarning, match="ignored on the ref path"):
        registry.dispatch("scan", x, impl="ref", block=64)
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # second call: warned once already
        registry.dispatch("scan", x, impl="ref", block=64)
        registry.dispatch("scan", x, impl="ref")  # no tiles: never warns
    monkeypatch.setenv("REPRO_STRICT_TILES", "1")
    with pytest.raises(ValueError, match="ignored on the ref path"):
        registry.dispatch("scan", x, impl="ref", block=64)
