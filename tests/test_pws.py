"""PWS scheduler theorems, measured on the simulated machine:
Obs. 4.3 (<= p-1 steals per priority), Cor. 4.1 (<= 2 p D' attempts),
priority monotonicity, cache-miss excess (Lemma 4.4), block-miss excess
(Lemma 4.8), PWS <= RWS block waits, gapping and padding effects."""
import math

import pytest

from repro.core import costmodel
from repro.core.algorithms import (
    BItoRMDirect,
    MSum,
    MTBI,
    bi_to_rm_gapped_programs,
    prefix_sums_programs,
    strassen_program,
)
from repro.core.hbp import Memory
from repro.core.machine import Machine
from repro.core.pws import PWS
from repro.core.rws import RWS

P, M, B = 8, 512, 16


def run(progs, p=P, M_=M, B_=B, sched=None, padded=False):
    m = Machine(p, M_, B_, scheduler=sched or PWS(), padded=padded)
    if isinstance(progs, list):
        return m.run_sequence(progs)
    return m.run(progs)


def seq_run(progs):
    """Sequential execution (p=1) => the sequential cache complexity Q."""
    return run(progs, p=1)


def test_steals_per_priority_bound():
    """Obs. 4.3: at most p-1 tasks of any priority stolen under PWS."""
    st = run(MSum(4096, Memory(B)))
    for pr, cnt in st.steals_per_priority().items():
        assert cnt <= P - 1, (pr, cnt)


def test_steal_priorities_nonincreasing():
    """PWS steals in rounds of non-increasing priority (chronological record
    order; within one BP computation the max available head size only
    shrinks)."""
    st = run(MSum(4096, Memory(B)))
    prios = [pr for _, pr, _, _ in st.steals]  # chronological
    violations = sum(1 for a, b in zip(prios, prios[1:]) if b > a)
    assert violations == 0, prios


def test_total_steal_attempts_bound():
    """Cor. 4.1: attempts <= 2 p D'."""
    n = 4096
    st = run(MSum(n, Memory(B)))
    n_priorities = int(math.log2(n)) + 2
    assert st.steal_attempts <= costmodel.steals_bound(P, n_priorities)


def test_scan_cache_excess_lemma_4_4():
    """Lemma 4.4(ii): excess <= c * p * M/B for scans."""
    n = 1 << 14
    q_seq = seq_run(MSum(n, Memory(B))).total_cache_misses()
    q_pws = run(MSum(n, Memory(B))).total_cache_misses()
    excess = q_pws - q_seq
    assert excess <= 4 * costmodel.pws_cache_excess_bp(P, M, B), (excess, q_seq)


def test_mt_cache_excess():
    n_mat = 64
    q_seq = seq_run(MTBI(n_mat, Memory(B))).total_cache_misses()
    q_pws = run(MTBI(n_mat, Memory(B))).total_cache_misses()
    assert q_pws - q_seq <= 4 * costmodel.pws_cache_excess_bp(P, M, B)


def test_block_miss_excess_L1_lemma_4_8():
    """Lemma 4.8(i): block misses O(p B log B) for L(r)=O(1) computations."""
    st = run(MSum(1 << 14, Memory(B)))
    bound = costmodel.pws_block_excess_bp(P, B, 1 << 14)
    assert st.total_block_misses() <= 2 * bound, (st.total_block_misses(), bound)


def test_pws_beats_rws_on_block_misses():
    """The paper's headline: deterministic PWS incurs fewer block misses than
    RWS on block-sharing computations (averaged over RWS seeds)."""
    def total(sched):
        return run(BItoRMDirect(64, Memory(B)), sched=sched).total_block_misses()

    pws = total(PWS())
    rws_avg = sum(total(RWS(seed=s)) for s in range(5)) / 5
    assert pws <= rws_avg * 1.05, (pws, rws_avg)


def test_gapping_reduces_block_misses():
    """§3.2: BI->RM (gap RM) has lower block-miss cost than the direct
    conversion, at the price of extra cache misses (bigger footprint)."""
    direct = run(BItoRMDirect(64, Memory(B)))
    gapped = run(bi_to_rm_gapped_programs(64, Memory(B)))
    assert gapped.total_block_misses() <= direct.total_block_misses(), (
        gapped.total_block_misses(), direct.total_block_misses())


def test_padded_stacks_no_worse():
    """Def. 3.3 / §4.7: padding separates stack frames; block misses do not
    increase."""
    plain = run(MSum(4096, Memory(B)), padded=False).total_block_misses()
    padded = run(MSum(4096, Memory(B)), padded=True).total_block_misses()
    assert padded <= plain + 2, (padded, plain)


def test_prefix_sums_sequence_under_pws():
    st = run(prefix_sums_programs(1 << 13, Memory(B)))
    q_seq = seq_run(prefix_sums_programs(1 << 13, Memory(B))).total_cache_misses()
    assert st.total_cache_misses() - q_seq <= 8 * costmodel.pws_cache_excess_bp(P, M, B)


def test_strassen_type2_runs_and_bounds():
    """Type 2 HBP (SEQ/FORK) executes correctly under PWS; cache excess within
    Lemma 4.1(iii) envelope; steals-per-priority still <= p-1."""
    st = run(strassen_program(16, Memory(B), base=4))
    assert st.accesses > 0
    for pr, cnt in st.steals_per_priority().items():
        assert cnt <= P - 1
    q_seq = seq_run(strassen_program(16, Memory(B), base=4)).total_cache_misses()
    bound = costmodel.pws_cache_excess_type2(P, M, B, 16 * 16, c=1, s_kind="quarter")
    assert st.total_cache_misses() - q_seq <= 8 * max(bound, 1)


def test_usurpations_bounded_by_steals():
    """Lemma 4.6-adjacent: usurpations happen only where steals happened."""
    st = run(MSum(4096, Memory(B)))
    assert st.usurpations <= 4 * max(len(st.steals), 1) + P
