import os
import sys
from pathlib import Path

# tests run on the single real CPU device (the dry-run sets its own flags in
# a separate process); keep compilation light
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
