import os
import sys
from pathlib import Path

# tests run on the single real CPU device (the dry-run sets its own flags in
# a separate process); keep compilation light
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)


@pytest.fixture(autouse=True)
def _reset_warn_once():
    """Warn/log-once registries (dispatch's dropped-override warning, the
    autotune interpolation log) must not leak across tests: a test that
    asserts 'warns once' would otherwise pass or fail depending on which
    test dispatched first."""
    from repro.kernels import registry

    registry.reset_warnings()
    yield
