"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip cleanly when absent
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import layouts, planner
from repro.launch.hlo_analysis import shape_bytes
from repro.models.moe_layer import SUBLANE, gapped_capacity


# -- planner: the balance condition as a hard invariant -------------------------

@given(st.lists(st.integers(1, 6), min_size=1, max_size=3),
       st.sampled_from(["wq", "wo", "embed", "e_gate", "ln1", "unknown_leaf"]))
@settings(max_examples=40, deadline=None)
def test_planner_never_emits_indivisible_specs(dim_pows, name):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shape = tuple(2 ** p for p in dim_pows)
    tree = {name: jax.ShapeDtypeStruct(shape, jnp.float32)}
    specs = planner.plan_params(tree, mesh)
    spec = specs[name]
    assert len(spec) == len(shape)
    for dim, entry in zip(shape, spec):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        assert dim % size == 0


# -- BI layout bijection ---------------------------------------------------------

@given(st.integers(1, 6))
@settings(max_examples=6, deadline=None)
def test_bi_perm_is_bijection(p):
    n = 2 ** p
    perm = layouts.rm_to_bi_perm(n)
    assert len(np.unique(perm)) == n * n


# -- gapping quanta ----------------------------------------------------------------

@given(st.integers(1, 100_000), st.integers(1, 256), st.integers(1, 16),
       st.floats(0.1, 4.0))
@settings(max_examples=60, deadline=None)
def test_gapped_capacity_invariants(n, e, k, cf):
    c = gapped_capacity(n, e, k, cf)
    assert c % SUBLANE == 0
    assert c >= SUBLANE
    # capacity covers the expected per-expert load under balance
    assert c * e >= min(n * k * cf, n * k) * 0.5 or c == SUBLANE


# -- prefix sums associativity (the BP combine) --------------------------------------

@given(st.integers(2, 400), st.integers(0, 5))
@settings(max_examples=25, deadline=None)
def test_two_pass_scan_equals_sequential(n, seed):
    from repro.core.algorithms_jax import prefix_sums

    x = jnp.asarray(np.random.default_rng(seed).standard_normal(n), jnp.float32)
    for block in (7, 64):
        np.testing.assert_allclose(prefix_sums(x, block=block), jnp.cumsum(x),
                                   rtol=2e-4, atol=2e-4)


# -- HLO shape parsing ---------------------------------------------------------------

@given(st.sampled_from(["f32", "bf16", "s32", "pred", "u8"]),
       st.lists(st.integers(1, 64), min_size=0, max_size=4))
@settings(max_examples=40, deadline=None)
def test_shape_bytes_roundtrip(dtype, dims):
    widths = {"f32": 4, "bf16": 2, "s32": 4, "pred": 1, "u8": 1}
    n = 1
    for d in dims:
        n *= d
    s = f"{dtype}[{','.join(map(str, dims))}]"
    assert shape_bytes(s) == n * widths[dtype]


# -- data pipeline determinism across instances ----------------------------------------

@given(st.integers(0, 50), st.integers(0, 3))
@settings(max_examples=10, deadline=None)
def test_batch_at_pure(step, seed):
    from repro.configs import get_smoke_config
    from repro.data import DataConfig, SyntheticLMDataset

    cfg = get_smoke_config("qwen3-1.7b")
    a = SyntheticLMDataset(DataConfig(seed=seed, global_batch=2, seq_len=32), cfg)
    b = SyntheticLMDataset(DataConfig(seed=seed, global_batch=2, seq_len=32), cfg)
    np.testing.assert_array_equal(a.batch_at(step)["tokens"], b.batch_at(step)["tokens"])
