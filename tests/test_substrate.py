"""Substrate: optimizer, schedules, compression, data pipeline, checkpoint,
fault tolerance, elastic resharding."""
import math
import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip cleanly when absent
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.configs import get_smoke_config
from repro.data import DataConfig, SyntheticLMDataset
from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    linear_warmup_cosine,
)
from repro.optim.compression import compress_int8, decompress_int8, ef_compress
from repro.runtime import FaultTolerantRunner, StragglerMonitor


# -- optimizer ---------------------------------------------------------------

def test_adamw_first_step_matches_closed_form():
    cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
                      grad_clip=0.0)
    params = {"w": jnp.ones((4,), jnp.float32)}
    grads = {"w": jnp.full((4,), 0.5, jnp.float32)}
    opt = adamw_init(params)
    new_params, new_opt, _ = adamw_update(params, grads, opt, cfg)
    # bias-corrected first step: mhat = g, vhat = g^2 -> delta = g/(|g|+eps) = 1
    np.testing.assert_allclose(np.asarray(new_params["w"]), 1.0 - 0.1, rtol=1e-5)
    assert int(new_opt["step"]) == 1


def test_adamw_grad_clip_applies():
    cfg = AdamWConfig(lr=0.1, grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros((1000,), jnp.float32)}
    grads = {"w": jnp.full((1000,), 10.0, jnp.float32)}
    opt = adamw_init(params)
    _, _, metrics = adamw_update(params, grads, opt, cfg)
    assert float(metrics["grad_norm"]) > 1.0  # reported pre-clip norm


def test_schedule_warmup_then_decay():
    fn = linear_warmup_cosine(1.0, warmup_steps=10, total_steps=110)
    assert float(fn(jnp.int32(0))) == 0.0
    assert float(fn(jnp.int32(10))) == pytest.approx(1.0, rel=1e-3)
    assert float(fn(jnp.int32(110))) < 0.2


# -- compression --------------------------------------------------------------

@given(st.integers(0, 10))
@settings(max_examples=10, deadline=None)
def test_int8_compression_bounded_error(seed):
    g = jax.random.normal(jax.random.key(seed), (256,), jnp.float32)
    q, s = compress_int8(g)
    back = decompress_int8(q, s)
    assert float(jnp.max(jnp.abs(back - g))) <= float(s) * 0.5 + 1e-6


def test_error_feedback_reduces_bias():
    """With EF, the accumulated compressed sum tracks the true sum."""
    rng = jax.random.split(jax.random.key(0), 50)
    grads = [jax.random.normal(k, (64,), jnp.float32) * 0.01 for k in rng]
    resid = {"g": jnp.zeros((64,), jnp.float32)}
    acc_c = jnp.zeros((64,))
    for g in grads:
        q, s, resid = ef_compress({"g": g}, resid)
        acc_c = acc_c + decompress_int8(q["g"], s["g"])
    acc_t = sum(grads)
    # residual carries the outstanding error: acc_c + resid == acc_t
    np.testing.assert_allclose(np.asarray(acc_c + resid["g"]), np.asarray(acc_t),
                               rtol=1e-3, atol=1e-4)


# -- data ----------------------------------------------------------------------

def test_data_determinism_and_packing():
    cfg = get_smoke_config("qwen3-1.7b")
    ds = SyntheticLMDataset(DataConfig(seed=3, global_batch=4, seq_len=64), cfg)
    b1, b2 = ds.batch_at(7), ds.batch_at(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 64)
    assert (b1["tokens"] < cfg.vocab_size).all()
    # packing: no padding id inside (fully packed)
    assert (b1["tokens"] != 0).mean() > 0.95
    b3 = ds.batch_at(8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_data_modality_extras():
    cfg = get_smoke_config("llama-3.2-vision-90b")
    ds = SyntheticLMDataset(DataConfig(global_batch=2, seq_len=32), cfg)
    b = ds.batch_at(0)
    assert b["image_embeds"].shape == (2, cfg.n_image_tokens, cfg.d_model)


# -- checkpoint ------------------------------------------------------------------

def _state():
    return {"params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
            "opt_state": {"step": jnp.int32(5), "m": {"w": jnp.ones((2, 3))}}}


def test_checkpoint_roundtrip(tmp_path):
    state = _state()
    save_checkpoint(tmp_path, 5, state)
    step, loaded = load_checkpoint(tmp_path, state)
    assert step == 5
    np.testing.assert_array_equal(loaded["params"]["w"], state["params"]["w"])


def test_checkpoint_checksum_detects_corruption(tmp_path):
    state = _state()
    d = save_checkpoint(tmp_path, 1, state)
    victim = sorted(d.glob("leaf_*.npy"))[0]
    raw = bytearray(victim.read_bytes())
    raw[-1] ^= 0xFF
    victim.write_bytes(bytes(raw))
    with pytest.raises(IOError):
        load_checkpoint(tmp_path, state)


def test_checkpoint_retention_and_atomicity(tmp_path):
    state = _state()
    for s in [1, 2, 3, 4, 5]:
        save_checkpoint(tmp_path, s, state, keep=2)
    steps = sorted(int(p.name.split("_")[1]) for p in Path(tmp_path).glob("step_*"))
    assert steps == [4, 5]
    assert not list(Path(tmp_path).glob("*.tmp"))


def test_async_checkpoint_manager(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save_async(7, _state())
    mgr.wait()
    step, loaded = mgr.restore_latest(_state())
    assert step == 7


# -- fault tolerance ----------------------------------------------------------------

def test_runner_retries_transient_failure(tmp_path):
    mgr = CheckpointManager(tmp_path)
    runner = FaultTolerantRunner(mgr, save_every=0, max_retries=2)
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient")
        return "ok"

    assert runner.run_step(0, flaky) == "ok"
    assert runner.retries == 2


def test_runner_gives_up_and_raises(tmp_path):
    mgr = CheckpointManager(tmp_path)
    runner = FaultTolerantRunner(mgr, save_every=0, max_retries=1)

    def always_fails():
        raise ValueError("hard")

    with pytest.raises(RuntimeError):
        runner.run_step(0, always_fails)


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(min_samples=10, k_sigma=3.0)
    for _ in range(20):
        assert not mon.observe(1.0 + np.random.default_rng(0).random() * 0.01)
    assert mon.observe(10.0)


def test_straggler_monitor_excludes_flagged_from_window():
    """Regression: a flagged sample must NOT enter the rolling window — one
    genuine straggler would otherwise inflate the std and mask the next
    (10.0 in a ~1.0 window pushes mean + 3*sigma past any moderate
    outlier)."""
    mon = StragglerMonitor(min_samples=10, k_sigma=3.0)
    rng = np.random.default_rng(0)
    for _ in range(20):
        mon.observe(1.0 + rng.random() * 0.01)
    assert mon.observe(10.0)
    assert 10.0 not in mon.times          # excluded from the stats window
    assert mon.observe(2.0)               # the next straggler still flags
    assert mon.flagged == 2


# -- elastic -----------------------------------------------------------------------

def test_elastic_reshard_roundtrip(tmp_path):
    """Save under one (trivial) mesh, restore under another plan."""
    import jax.sharding as shd

    from repro.runtime.elastic import replan_for_mesh

    state = _state()
    save_checkpoint(tmp_path, 2, state, mesh_shape={"data": 1, "model": 1})
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    plan = replan_for_mesh(
        {"params": state["params"],
         "opt_state": {"step": state["opt_state"]["step"],
                       "master": state["params"], "m": state["params"],
                       "v": state["params"]}},
        mesh,
    )
    assert isinstance(jax.tree.leaves(plan["params"])[0], shd.NamedSharding)
