"""Type 2/3 HBP simulator programs: six-step FFT and list-ranking phases
(with the paper's list gapping) under PWS."""
import math

import pytest

from repro.core import costmodel
from repro.core.algorithms import fft_program, list_ranking_phase_programs
from repro.core.hbp import Memory
from repro.core.machine import Machine
from repro.core.pws import PWS
from repro.core.rws import RWS

P, M, B = 8, 512, 16


def run(progs, p=P, sched=None):
    m = Machine(p, M, B, scheduler=sched or PWS())
    return m.run_sequence(progs) if isinstance(progs, list) else m.run(progs)


def test_fft_program_runs_under_pws():
    st = run(fft_program(1 << 8, Memory(B)))
    assert st.accesses > 0
    for pr, cnt in st.steals_per_priority().items():
        assert cnt <= P - 1, (pr, cnt)


def test_fft_work_slope_n_log_n():
    """W(n) = O(n log n): slope of accesses vs n just above 1."""
    ns = [1 << 6, 1 << 8, 1 << 10]
    W = []
    for n in ns:
        st = run(fft_program(n, Memory(B)), p=1)
        W.append(st.accesses)
    lx = [math.log2(n) for n in ns]
    ly = [math.log2(w) for w in W]
    slope = (ly[-1] - ly[0]) / (lx[-1] - lx[0])
    assert 1.0 <= slope <= 1.6, (slope, W)


def test_fft_cache_excess_within_lemma_4_1():
    """Lemma 4.1(ii): c=2, s(n)=sqrt(n) => excess O(p M/B log n / log M)."""
    n = 1 << 10
    q_seq = run(fft_program(n, Memory(B)), p=1).total_cache_misses()
    q_pws = run(fft_program(n, Memory(B))).total_cache_misses()
    bound = costmodel.pws_cache_excess_type2(P, M, B, n, c=2, s_kind="sqrt")
    assert q_pws - q_seq <= 8 * bound, (q_pws - q_seq, bound)


def test_lr_gapping_stops_block_misses_for_small_lists():
    """§3.2: with gapping, contraction phases with m <= n/B^2 incur no block
    misses; without it the compacted phases keep sharing blocks."""
    n = 1 << 12

    def phase_block_misses(gapped):
        mem = Memory(B)
        progs = list_ranking_phase_programs(n, mem, gapped=gapped)
        machine = Machine(P, M, B, scheduler=PWS())
        per_phase = []
        for prog in progs:
            before = machine.stats.total_block_misses()
            machine.run(prog)
            per_phase.append(machine.stats.total_block_misses() - before)
        return per_phase

    g = phase_block_misses(True)
    c = phase_block_misses(False)
    # late (small) phases: gapped spreads them across blocks
    assert sum(g[-2:]) <= sum(c[-2:]) + 1, (g, c)
    # totals never worse with gapping
    assert sum(g) <= sum(c) + 2, (g, c)


def test_lr_phases_geometric_work():
    """Total work across phases is O(n) (geometric contraction)."""
    n = 1 << 12
    progs = list_ranking_phase_programs(n, Memory(B))
    total_leaves = sum(p.n for p in progs)
    assert total_leaves <= 2 * n
