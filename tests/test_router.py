"""Multi-replica router: placement determinism, fleet fault tolerance.

The acceptance bar for ``repro.launch.router``: whatever the routing arm
(deterministic ``pws`` match rounds or seeded ``rws`` two-choice), the
placements, the in-flight migrations, a replica death mid-decode, and
elastic join/leave, every request's greedy tokens are IDENTICAL,
request-for-request, to a clean single-replica engine run — randomness and
failures perturb *placement*, never tokens.  The ``rws`` two-choice core is
unit-tested without a fleet.
"""
import random

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import rws
from repro.launch.engine import Engine
from repro.launch.mesh import make_debug_mesh
from repro.launch.router import Router
from repro.launch.serve import Request
from repro.models.base import RunOptions
from repro.runtime import FaultInjector

ENGINE_KW = dict(max_batch=2, max_len=64, chunk=8, snapshot_every=2)


@pytest.fixture(scope="module")
def mesh():
    return make_debug_mesh(tp=min(2, len(jax.devices())))


@pytest.fixture(scope="module")
def cfg():
    return get_smoke_config("qwen3-1.7b")


@pytest.fixture(autouse=True)
def _clear_autotune_pin():
    from repro.kernels import autotune
    yield
    autotune.set_mode(None)


def _spec(cfg, n=6, *, seed=0, max_new=6):
    """Skewed workload spec: ragged prompts, mixed generation budgets."""
    rng = np.random.default_rng(seed)
    return [(rng.integers(3, cfg.vocab_size,
                          int(rng.integers(4, 20))).astype(np.int32),
             int(rng.integers(2, max_new + 1)))
            for _ in range(n)]


def _reqs(spec):
    return [Request(i, p, max_new=mn) for i, (p, mn) in enumerate(spec)]


def _kw():
    return dict(ENGINE_KW, opts=RunOptions())


def _assert_single_replica_parity(router, spec, reqs):
    """The oracle: a clean 1-replica engine sharing replica 0's params
    serves the same workload; tokens must match request-for-request."""
    single = Engine(router.cfg, router.mesh, injector=FaultInjector(""),
                    **_kw())
    single.params = router.replicas[0].engine.params
    alone = _reqs(spec)
    single.run(alone)
    assert [r.out for r in alone] == [r.out for r in reqs], \
        "router tokens diverge from the clean single-replica run"


# -- rws two-choice core (no fleet) -------------------------------------------

def test_two_choice_prefers_lighter_lower_id_on_tie():
    # two ids: the two distinct samples always see both; lighter wins
    assert rws.two_choice(random.Random(0), [0, 1], {0: 9, 1: 2}) == 1
    assert rws.two_choice(random.Random(1), [0, 1], {0: 2, 1: 9}) == 0
    # equal loads: lower id breaks the tie
    assert rws.two_choice(random.Random(2), [0, 1], {0: 5, 1: 5}) == 0
    # a single candidate needs no coin
    assert rws.two_choice(random.Random(3), [7], {7: 0}) == 7


def test_two_choice_is_seeded_and_samples_both():
    ids = [0, 1, 2, 3]
    load = {i: i for i in ids}
    picks = [rws.two_choice(random.Random(11), ids, load) for _ in range(8)]
    assert len(set(picks)) == 1                 # same seed, same pick
    rng = random.Random(4)
    seen = {rws.two_choice(rng, ids, load) for _ in range(64)}
    assert len(seen) >= 2                       # the coin really varies
    assert 3 not in seen                        # heaviest never beats a pair


# -- routing arms: determinism + token identity -------------------------------

def test_router_pws_deterministic_balanced_token_identical(mesh, cfg):
    """The deterministic arm: same workload → identical placements run
    after run, both replicas receive work on a skewed workload, the
    match-round invariants (asserted inside ``_route_pws``) hold, and the
    tokens equal the clean single-replica oracle."""
    spec = _spec(cfg)
    router = Router(cfg, mesh, n_replicas=2, route="pws", **_kw())
    a = _reqs(spec)
    out1 = router.run(a)
    b = _reqs(spec)
    out2 = router.run(b)
    assert out1["placements"] == out2["placements"]
    assert [r.out for r in a] == [r.out for r in b]
    routed = out1["counters"]["routed"]
    assert routed[0] > 0 and routed[1] > 0
    assert {u for u, _ in out1["placements"]} == {r.uid for r in a}
    assert out1["counters"]["route_rounds"] > 0
    _assert_single_replica_parity(router, spec, a)


def test_router_rws_seeded_balanced_token_identical(mesh, cfg):
    """The randomized arm: the seed fixes the placement sequence (re-seeded
    per ``begin``), two-choice spreads a skewed workload over both
    replicas, and tokens still equal the deterministic oracle — randomness
    perturbs placement only."""
    spec = _spec(cfg)
    router = Router(cfg, mesh, n_replicas=2, route="rws", seed=5, **_kw())
    a = _reqs(spec)
    out1 = router.run(a)
    b = _reqs(spec)
    out2 = router.run(b)
    assert out1["placements"] == out2["placements"]
    routed = out1["counters"]["routed"]
    assert routed[0] > 0 and routed[1] > 0
    _assert_single_replica_parity(router, spec, a)


# -- replica death → checkpoint-streamed respawn ------------------------------

def test_router_replica_death_respawns_token_identical(mesh, cfg):
    """Failure-model tier (d): replica 1's decode launches fail through the
    retry budget, the escalated ``LaunchFailedError`` marks it dead, its
    in-flight requests re-queue router-wide with their host snapshots, and
    a replacement streams up from the fleet checkpoint — with every token
    identical to a clean single-replica run."""
    spec = _spec(cfg, n=6, max_new=8)
    router = Router(cfg, mesh, n_replicas=2, route="pws",
                    fleet_faults="|decode@3=raise:99", **_kw())
    reqs = _reqs(spec)
    out = router.run(reqs)
    c = out["counters"]
    assert c["replica_deaths"] == 1
    assert c["replica_restarts"] >= 1
    assert c["requeued_on_death"] >= 1
    assert c["migrations"] >= 1        # >= 1 cross-replica snapshot resume
    assert router.replicas[1].state == "dead"
    assert any(r.rid >= 2 and r.spawned_from == "checkpoint"
               and r.state == "live" for r in router.replicas)
    assert all(len(r.out) == r.max_new for r in reqs)
    _assert_single_replica_parity(router, spec, reqs)


# -- in-flight rebalancing ----------------------------------------------------

def test_router_rebalance_migrates_decode_slot_exactly(mesh, cfg):
    """Queue-depth skew rebalancing: one long request next to shorts leaves
    the fleet skewed once the shorts drain; the router drains the donor's
    decoding slot and the recipient resumes it from the host snapshot —
    slot migration is token-exact and the recipient really restores (its
    ``snapshot_restores`` counter moves)."""
    spec = [(np.arange(3, 15, dtype=np.int32), 20),
            (np.arange(3, 11, dtype=np.int32), 2),
            (np.arange(4, 12, dtype=np.int32), 2),
            (np.arange(5, 13, dtype=np.int32), 2)]
    router = Router(cfg, mesh, n_replicas=2, route="pws",
                    rebalance_threshold=4, queue_depth=0, **_kw())
    reqs = _reqs(spec)
    out = router.run(reqs)
    c = out["counters"]
    assert c["rebalances"] >= 1
    assert c["slot_migrations"] >= 1
    assert c["migrations"] >= 1
    restores = sum(row["faults"]["snapshot_restores"]
                   for row in out["replicas"])
    assert restores >= 1
    _assert_single_replica_parity(router, spec, reqs)


# -- elastic join / leave -----------------------------------------------------

def test_router_elastic_join_and_leave_token_identical(mesh, cfg):
    """Live re-mesh: a replica joins mid-run (checkpoint-streamed, starts
    taking placements), another leaves (its queue and in-flight decodes
    drain back through the snapshot path) — the fleet finishes every
    request token-identically."""
    spec = _spec(cfg, n=10, seed=2, max_new=8)
    router = Router(cfg, mesh, n_replicas=2, route="pws", **_kw())
    reqs = _reqs(spec)
    router.begin(reqs)
    for _ in range(2):
        router.step_round()
    joiner = router.add_replica()
    assert joiner.spawned_from == "checkpoint"
    for _ in range(2):
        router.step_round()
    router.remove_replica(1)
    while not router.done():
        router.step_round()
    out = router.finish(reqs)
    c = out["counters"]
    assert c["joins"] == 1 and c["leaves"] == 1
    assert c["routed"].get(joiner.rid, 0) >= 1
    states = {r.rid: r.state for r in router.replicas}
    assert states[1] == "left" and states[joiner.rid] == "live"
    assert all(len(r.out) == r.max_new for r in reqs)
    _assert_single_replica_parity(router, spec, reqs)


def test_router_remove_guards(mesh, cfg):
    router = Router(cfg, mesh, n_replicas=2, route="pws", **_kw())
    router.remove_replica(1)
    with pytest.raises(ValueError, match="not live"):
        router.remove_replica(1)
    with pytest.raises(ValueError, match="last live"):
        router.remove_replica(0)


# -- health-score load shedding -----------------------------------------------

def test_router_health_shedding_routes_around_faulty_replica(mesh, cfg):
    """A replica whose launches keep failing folds its PR-9 retry counters
    into a health score under the shed threshold; the router stops placing
    new work there (sheds counted) while the healthy replica finishes the
    queue — tokens still exact."""
    spec = _spec(cfg, n=10, seed=3, max_new=6)
    plan = "|decode@1=raise,decode@2=raise"
    router = Router(cfg, mesh, n_replicas=2, route="pws",
                    fleet_faults=plan, degrade_after=2, degrade_window=16,
                    heal_after=64, **_kw())
    reqs = _reqs(spec)
    out = router.run(reqs)
    sick = router.replicas[1]
    assert sick.state == "live"                  # retries recovered, no death
    assert sick.health < 0.5 and sick.shed()
    assert out["counters"]["sheds"] >= 1
    assert out["counters"]["replica_deaths"] == 0
    _assert_single_replica_parity(router, spec, reqs)


# -- provenance rows ----------------------------------------------------------

def test_router_provenance_rows(mesh, cfg):
    """Every replica contributes a provenance row: identity, how it was
    born, its mesh, the kernel policy description and autotune table
    provenance, and the live health/fault picture."""
    spec = _spec(cfg, n=4)
    router = Router(cfg, mesh, n_replicas=2, route="pws", **_kw())
    out = router.run(_reqs(spec))
    rows = out["replicas"]
    assert [row["rid"] for row in rows] == [0, 1]
    assert [row["spawned_from"] for row in rows] == ["init", "checkpoint"]
    for row in rows:
        assert row["state"] == "live"
        assert row["mesh"] == dict(mesh.shape)
        assert isinstance(row["policy"], str) and row["policy"]
        assert "mode" in row["autotune"]
        assert 0.0 <= row["health"] <= 1.0
        assert "retries" in row["faults"]
