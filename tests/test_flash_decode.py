"""Decode-path + backward flash attention.

Covers the two attention ROADMAP items landed together: cached decode on the
Pallas kernel (``q_offset`` / ``kv_len``, static grid shrink and traced
no-recompile paths, ragged shapes, fully-masked rows) and the custom VJP
(recomputation backward kernels), plus the model-layer routing — under a
``policy.apply(impl={"attention": "pallas"})`` scope,
``models.common.attention`` reaches the kernel in interpret mode for decode
*and* under autodiff, with value and gradient parity against the jnp paths.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import policy, ref, registry
from repro.kernels.flash_attention import flash_attention
from repro.models import common

ATOL = 1e-5


def _qkv(bh, sq, sk, hd, seed=0):
    keys = jax.random.split(jax.random.key(seed), 3)
    return (jax.random.normal(keys[0], (bh, sq, hd)),
            jax.random.normal(keys[1], (bh, sk, hd)),
            jax.random.normal(keys[2], (bh, sk, hd)))


# -- decode forward -----------------------------------------------------------

@pytest.mark.parametrize("pos", [0, 63, 200, 255])
def test_decode_parity_static_kv_len(pos):
    """sq=1 over a 256-slot cache: static kv_len shrinks the KV grid, output
    matches the oracle at decode positions across the cache."""
    q, k, v = _qkv(2, 1, 256, 64, seed=pos)
    out = flash_attention(q, k, v, causal=True, q_offset=pos, kv_len=pos + 1,
                          q_block=1, kv_block=64)
    want = ref.flash_attention_ref(q, k, v, causal=True, q_offset=pos,
                                   kv_len=pos + 1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=ATOL)


def test_decode_traced_offset_no_recompile():
    """The serving loop's shape: one jitted function, the step position a
    traced scalar — every position runs through the same compilation."""
    q, k, v = _qkv(2, 1, 256, 64)

    calls = []

    @jax.jit
    def step(pos):
        calls.append(1)  # traced once, replayed for every pos
        return flash_attention(q, k, v, causal=True, q_offset=pos,
                               kv_len=pos + 1, q_block=1, kv_block=64)

    for pos in (0, 17, 255):
        out = step(jnp.int32(pos))
        want = ref.flash_attention_ref(q, k, v, causal=True, q_offset=pos,
                                       kv_len=pos + 1)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=ATOL)
    assert len(calls) == 1


def test_chunked_prefill_offset():
    """A prefill chunk (sq > 1) at a nonzero offset into the cache."""
    q, k, v = _qkv(2, 64, 256, 32)
    out = flash_attention(q, k, v, causal=True, q_offset=64, kv_len=128,
                          q_block=32, kv_block=64)
    want = ref.flash_attention_ref(q, k, v, causal=True, q_offset=64,
                                   kv_len=128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=ATOL)


@pytest.mark.parametrize("sq,sk,qb,kb", [
    (96, 96, 64, 64),    # blocks snap to 32/32
    (60, 60, 64, 64),    # snap to the dim itself
    (2, 254, 64, 64),    # 127*2: degenerate snap -> jnp-oracle fallback
    (2, 127, 64, 64),    # prime: degenerate snap -> jnp-oracle fallback
])
def test_ragged_shapes_snap_instead_of_crash(sq, sk, qb, kb):
    """Non-divisor blocks snap to divisors; a degenerate snap (sub-sublane
    tile on a long axis) falls back to the oracle (the old assert crashed)."""
    q, k, v = _qkv(2, sq, sk, 32)
    out = flash_attention(q, k, v, causal=sq == sk, q_block=qb, kv_block=kb)
    want = ref.flash_attention_ref(q, k, v, causal=sq == sk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=ATOL)


def test_fully_masked_rows_are_zero_and_match_ref():
    """window > 0 with the query offset beyond every valid key: every score
    is masked, the l_safe guard emits zeros, and the oracle agrees (instead
    of silently averaging v through a uniform softmax)."""
    q, k, v = _qkv(2, 4, 64, 32)
    out = flash_attention(q, k, v, causal=False, window=16, q_offset=500,
                          kv_len=64, q_block=4, kv_block=32)
    want = ref.flash_attention_ref(q, k, v, causal=False, window=16,
                                   q_offset=500, kv_len=64)
    np.testing.assert_array_equal(np.asarray(out), np.zeros_like(out))
    np.testing.assert_array_equal(np.asarray(want), np.zeros_like(want))


# -- per-row KV lengths (continuous batching) ---------------------------------

def test_per_row_kv_len_matches_scalar_loop():
    """A (rows,) kv_len/q_offset vector produces exactly what running each
    row alone with scalar arguments produces — the per-lane SMEM reads don't
    leak one row's length into another's."""
    lens = np.array([5, 17, 64, 33], np.int32)
    offs = lens - 1  # each row decoding its next token
    q, k, v = _qkv(4, 1, 64, 32)
    out = flash_attention(q, k, v, causal=True, q_offset=offs, kv_len=lens,
                          q_block=1, kv_block=32)
    for i in range(4):
        alone = flash_attention(q[i:i + 1], k[i:i + 1], v[i:i + 1],
                                causal=True, q_offset=int(offs[i]),
                                kv_len=int(lens[i]), q_block=1, kv_block=32)
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(alone[0]),
                                   atol=ATOL, err_msg=f"row {i}")
    want = ref.flash_attention_ref(q, k, v, causal=True, q_offset=offs,
                                   kv_len=lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=ATOL)


def test_per_row_kv_len_gqa_head_fold():
    """rows < bh: each row's scalar fans out over its bh // rows folded
    heads (the batch-major head fold of the model layer)."""
    lens = np.array([9, 40], np.int32)
    q, k, v = _qkv(8, 1, 64, 32, seed=2)  # 2 rows x 4 heads
    out = flash_attention(q, k, v, causal=True, q_offset=lens - 1,
                          kv_len=lens, q_block=1, kv_block=32)
    want = ref.flash_attention_ref(q, k, v, causal=True, q_offset=lens - 1,
                                   kv_len=lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=ATOL)


def test_per_row_traced_vector_no_recompile():
    """The engine's decode shape: one jitted step, per-row positions and
    lengths traced (rows,) vectors — every ragged batch composition replays
    the same compilation."""
    q, k, v = _qkv(4, 1, 256, 64)

    calls = []

    @jax.jit
    def step(offs, lens):
        calls.append(1)
        return flash_attention(q, k, v, causal=True, q_offset=offs,
                               kv_len=lens, q_block=1, kv_block=64)

    for lens in ([1, 64, 200, 256], [17, 17, 17, 17], [3, 255, 9, 128]):
        lens = np.asarray(lens, np.int32)
        out = step(jnp.asarray(lens - 1), jnp.asarray(lens))
        want = ref.flash_attention_ref(q, k, v, causal=True,
                                       q_offset=lens - 1, kv_len=lens)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=ATOL)
    assert len(calls) == 1


def test_per_row_zero_length_rows_are_zero():
    """kv_len == 0 on some rows (empty slots parked in the batch): those
    lanes emit exact zeros via the l_safe guard; live rows are untouched."""
    lens = np.array([0, 32, 0, 7], np.int32)
    q, k, v = _qkv(4, 1, 64, 32, seed=5)
    out = flash_attention(q, k, v, causal=True,
                          q_offset=np.maximum(lens - 1, 0), kv_len=lens,
                          q_block=1, kv_block=32)
    np.testing.assert_array_equal(np.asarray(out[0]), np.zeros_like(out[0]))
    np.testing.assert_array_equal(np.asarray(out[2]), np.zeros_like(out[2]))
    want = ref.flash_attention_ref(q, k, v, causal=True,
                                   q_offset=np.maximum(lens - 1, 0),
                                   kv_len=lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=ATOL)


def test_per_row_kv_len_int8_kv():
    """Per-row lengths compose with the int8 KV cache: per-head scales
    apply under ragged masking with parity against the dequantized oracle."""
    lens = np.array([11, 64, 29, 48], np.int32)
    q, k, v = _qkv(4, 1, 64, 32, seed=7)
    k_scale = jnp.abs(k).max(axis=(1, 2), keepdims=True) / 127.0
    v_scale = jnp.abs(v).max(axis=(1, 2), keepdims=True) / 127.0
    k8 = jnp.clip(jnp.round(k / k_scale), -127, 127).astype(jnp.int8)
    v8 = jnp.clip(jnp.round(v / v_scale), -127, 127).astype(jnp.int8)
    out = flash_attention(q, k8, v8, causal=True, q_offset=lens - 1,
                          kv_len=lens, k_scale=k_scale, v_scale=v_scale,
                          q_block=1, kv_block=32)
    want = ref.flash_attention_ref(q, k8 * k_scale, v8 * v_scale,
                                   causal=True, q_offset=lens - 1,
                                   kv_len=lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-4)


def test_per_row_vjp_grads_match_ref():
    """The backward kernels honor per-row vectors: chunked-prefill grads at
    ragged offsets match the oracle, and each row's dead cache slots get
    exactly zero dk/dv."""
    lens = np.array([48, 96], np.int32)
    offs = lens - 32
    q, k, v = _qkv(2, 32, 128, 32, seed=9)

    def loss_kernel(q, k, v):
        o = flash_attention(q, k, v, causal=True, q_offset=offs, kv_len=lens,
                            q_block=32, kv_block=32)
        return jnp.sum(o * o)

    def loss_ref(q, k, v):
        o = ref.flash_attention_ref(q, k, v, causal=True, q_offset=offs,
                                    kv_len=lens)
        return jnp.sum(o * o)

    got = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    want = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for g, w, name in zip(got, want, "qkv"):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), atol=1e-4,
                                   err_msg=f"d{name}")
    for i, n in enumerate(lens):
        assert float(jnp.abs(got[1][i, n:]).max()) == 0.0
        assert float(jnp.abs(got[2][i, n:]).max()) == 0.0


def test_per_row_vector_length_must_divide_batch():
    q, k, v = _qkv(4, 1, 64, 32)
    with pytest.raises(ValueError):
        flash_attention(q, k, v, causal=True, kv_len=np.array([3, 5, 7]))


# -- the custom VJP -----------------------------------------------------------

@pytest.mark.parametrize("causal,window", [(True, 0), (True, 40), (False, 0)])
def test_vjp_grads_match_ref(causal, window):
    q, k, v = _qkv(2, 128, 128, 32)

    def loss_kernel(q, k, v):
        o = flash_attention(q, k, v, causal=causal, window=window,
                            q_block=32, kv_block=32)
        return jnp.sum(o * o)

    def loss_ref(q, k, v):
        o = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
        return jnp.sum(o * o)

    got = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    want = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for g, w, name in zip(got, want, "qkv"):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), atol=1e-4,
                                   err_msg=f"d{name}")


def test_vjp_grads_with_offsets():
    """The backward kernels honor q_offset/kv_len: grads of a chunked
    (offset) forward match grads of the oracle with the same mask, and
    masked-out cache slots get exactly zero dk/dv."""
    q, k, v = _qkv(2, 32, 128, 32)

    def loss_kernel(q, k, v):
        o = flash_attention(q, k, v, causal=True, q_offset=32, kv_len=64,
                            q_block=32, kv_block=32)
        return jnp.sum(o * o)

    def loss_ref(q, k, v):
        o = ref.flash_attention_ref(q, k, v, causal=True, q_offset=32,
                                    kv_len=64)
        return jnp.sum(o * o)

    got = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    want = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for g, w, name in zip(got, want, "qkv"):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), atol=1e-4,
                                   err_msg=f"d{name}")
    assert float(jnp.abs(got[1][:, 64:]).max()) == 0.0  # dead slots: dk == 0
    assert float(jnp.abs(got[2][:, 64:]).max()) == 0.0


def test_registry_attention_has_backward_entry():
    spec = registry.get("attention")
    assert spec.has_vjp
    # matmul gained its own custom VJP in PR 4 (model matmuls train through
    # the kernel route); scan remains forward-only
    assert registry.get("matmul").has_vjp
    assert not registry.get("scan").has_vjp


# -- model-layer routing ------------------------------------------------------

@pytest.fixture
def force_pallas(monkeypatch):
    """Scope an execution policy forcing attention onto the Pallas path (as
    'auto' resolves on TPU) while supported() stays False, so dispatch runs
    the kernel in interpret mode; wrap the spec's pallas hook to count that
    the kernel really ran."""
    calls = []
    spec = registry.get("attention")

    def counting_pallas(*args, **kwargs):
        calls.append(kwargs.keys())
        return spec.pallas(*args, **kwargs)

    monkeypatch.setitem(registry._REGISTRY, "attention",
                        dataclasses.replace(spec, pallas=counting_pallas))
    with policy.apply(impl={"attention": "pallas"}):
        yield calls


def _model_qkv(b, sq, sk, h, kvh, hd, seed=0):
    keys = jax.random.split(jax.random.key(seed), 3)
    return (jax.random.normal(keys[0], (b, sq, h, hd)),
            jax.random.normal(keys[1], (b, sk, kvh, hd)),
            jax.random.normal(keys[2], (b, sk, kvh, hd)))


def test_attention_policy_routes_decode_through_kernel(force_pallas):
    """Under the pallas policy scope, a decode call (sq=1 over a 256-slot
    cache, GQA heads) runs the registry's Pallas kernel in interpret mode
    and matches the jnp (dense) decode path (a nested jnp scope)."""
    q, k, v = _model_qkv(2, 1, 256, 4, 2, 32)
    pos = jnp.full((1,), 100, jnp.int32)
    kp = jnp.arange(256, dtype=jnp.int32)
    got = common.attention(q, k, v, pos, kp, causal=True,
                           q_block=64, kv_block=64)
    assert force_pallas, "decode did not reach the Pallas kernel"
    with policy.apply(impl={"attention": "jnp"}):
        want = common.attention(q, k, v, pos, kp, causal=True,
                                q_block=64, kv_block=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=ATOL)


def test_attention_policy_routes_autodiff_through_kernel(force_pallas):
    """The pallas policy under jax.grad: the kernel's custom VJP serves the
    backward (no routing around it), with gradient parity against the jnp
    path's flash VJP."""
    q, k, v = _model_qkv(2, 128, 128, 4, 2, 32)
    pos = jnp.arange(128, dtype=jnp.int32)

    def loss(q, k, v):
        o = common.attention(q, k, v, pos, pos, causal=True,
                             q_block=64, kv_block=64)
        return jnp.sum(o * o)

    got_val = loss(q, k, v)
    got = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    assert force_pallas, "autodiff call did not reach the Pallas kernel"
    with policy.apply(impl={"attention": "jnp"}):
        want_val = loss(q, k, v)
        want = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    np.testing.assert_allclose(float(got_val), float(want_val), rtol=1e-5)
    for g, w, name in zip(got, want, "qkv"):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), atol=1e-4,
                                   err_msg=f"d{name}")


def test_attention_dense_gqa_decode_numerics_unchanged():
    """The no-repeat GQA einsum in attention_dense matches the old
    materializing formula (f32 scores, repeated cache) on a decode step."""
    q, k, v = _model_qkv(2, 1, 128, 8, 2, 32, seed=3)
    pos = jnp.full((1,), 90, jnp.int32)
    kp = jnp.arange(128, dtype=jnp.int32)
    got = common.attention_dense(q, k, v, pos, kp, causal=True)

    kr = common.repeat_kv(k, 4)
    vr = common.repeat_kv(v, 4)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kr,
                        preferred_element_type=jnp.float32) / np.sqrt(32)
    scores = scores + common._mask_bias(pos, kp, causal=True,
                                        window=None)[None, None]
    probs = jax.nn.softmax(scores, axis=-1)
    want = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(vr.dtype), vr,
                      preferred_element_type=jnp.float32).astype(q.dtype)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=ATOL)


def test_planner_decode_regime():
    """plan_attention flips into the decode regime for tiny sq over a long
    KV axis: the whole query is one block and the KV panel deepens."""
    from repro.kernels import planner

    dp = planner.DeviceParams("cpu", "test", 8 * 2**20, 64)
    plan = planner.plan_attention(1, 4096, 64, jnp.float32, dp)
    assert plan["q_block"] == 1
    assert 4096 % plan["kv_block"] == 0
    square = planner.plan_attention(4096, 4096, 64, jnp.float32, dp)
    assert plan["kv_block"] >= square["kv_block"]  # budget shifts to KV
