"""DecodeCache layouts: RingKV wrap-around kernel parity + layering.

The RingKV layout maps a wrapped window buffer onto the flash kernel's
per-row ``q_offset``/``kv_len`` SMEM vectors (raw slots + causal softmax
permutation-invariance); these tests pin that mapping against a scalar
python loop that gathers each row's live window in chronological order —
per-row cursors at non-tile-aligned depths, wrapped and unwrapped rows in
one launch, both the pallas and jnp routes.  The kernel's ``kv_len == 0``
exact-zero contract and the int8 LinearKV scale path get the same oracle
treatment.  A source-level layering test keeps cache mutation idioms out
of the family modules — every slab write must go through
``repro.models.cache``.
"""
import inspect
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import policy
from repro.models import cache as dcache
from repro.models import common


def _rng_kv(rng, b, n, kvh, hd):
    return rng.standard_normal((b, n, kvh, hd)).astype(np.float32)


def _scalar_oracle(q, k_rows, v_rows):
    """One row, one query token: python-loop softmax over the row's keys in
    chronological order.  q (h, hd); k_rows/v_rows (n, kvh, hd)."""
    h, hd = q.shape
    kvh = k_rows.shape[1]
    group = h // kvh
    out = np.zeros((h, hd), np.float32)
    for hh in range(h):
        kk = k_rows[:, hh // group]            # (n, hd)
        vv = v_rows[:, hh // group]
        scores = kk @ q[hh] / np.sqrt(hd)
        scores = scores - scores.max()
        p = np.exp(scores)
        p /= p.sum()
        out[hh] = p @ vv
    return out


def _ring_fill(rng, b, cap, kvh, hd, positions):
    """Build ring slabs holding each row's live window: token at position
    ``p`` sits in slot ``p % cap``; dead slots hold garbage."""
    k = rng.standard_normal((b, cap, kvh, hd)).astype(np.float32) * 100.0
    v = rng.standard_normal((b, cap, kvh, hd)).astype(np.float32) * 100.0
    tok_k, tok_v = {}, {}
    for row, last in enumerate(positions):
        n = min(last + 1, cap)
        for p in range(last + 1 - n, last + 1):
            tok_k[(row, p)] = rng.standard_normal((kvh, hd)).astype(np.float32)
            tok_v[(row, p)] = rng.standard_normal((kvh, hd)).astype(np.float32)
            k[row, p % cap] = tok_k[(row, p)]
            v[row, p % cap] = tok_v[(row, p)]
    return k, v, tok_k, tok_v


# positions: wrapped at non-tile-aligned cursors (37, 53), exactly-full
# (31), partial (5), and a fresh row (0) — one launch, per-row vectors
RING_POSITIONS = [37, 5, 31, 0, 53, 12, 40, 7]


@pytest.mark.parametrize("route", ["pallas", "jnp"])
def test_ringkv_wrap_matches_scalar_oracle(route):
    """The decode attend over a RingKV slab — kernel route via per-row
    q_offset/kv_len, jnp route via slot_positions masking — equals the
    scalar chronological-gather oracle on every row, wrapped or not."""
    rng = np.random.default_rng(0)
    b, cap, h, kvh, hd = len(RING_POSITIONS), 32, 4, 2, 64
    positions = np.asarray(RING_POSITIONS, np.int32)
    k, v, tok_k, tok_v = _ring_fill(rng, b, cap, kvh, hd, positions)
    kv = dcache.RingKV(k=jnp.asarray(k), v=jnp.asarray(v),
                       pos=jnp.asarray(positions), b_axis=0)
    q = rng.standard_normal((b, 1, h, hd)).astype(np.float32)

    with policy.apply(impl={"attention": route if route == "pallas"
                            else "jnp"}):
        out = common.attention(
            jnp.asarray(q), kv.k, kv.v, jnp.asarray(positions)[:, None],
            kv.slot_positions(positions), causal=True, window=None,
            kv_len=kv.attend_lens(positions))
    out = np.asarray(out)

    for row, last in enumerate(positions):
        n = min(last + 1, cap)
        ps = range(last + 1 - n, last + 1)
        k_rows = np.stack([tok_k[(row, p)] for p in ps])
        v_rows = np.stack([tok_v[(row, p)] for p in ps])
        want = _scalar_oracle(q[row, 0], k_rows, v_rows)
        np.testing.assert_allclose(out[row, 0], want, rtol=2e-4, atol=2e-5,
                                   err_msg=f"row {row} pos {last}")


def test_kernel_zero_length_rows_emit_exact_zeros():
    """The flash kernel's per-row contract: a lane with ``kv_len == 0``
    attends nothing and emits EXACT zeros (the l_safe guard), while its
    neighbours in the same launch are untouched."""
    rng = np.random.default_rng(1)
    b, s, h, kvh, hd = 4, 16, 4, 2, 64
    q = jnp.asarray(rng.standard_normal((b, 1, h, hd)).astype(np.float32))
    k = jnp.asarray(_rng_kv(rng, b, s, kvh, hd))
    v = jnp.asarray(_rng_kv(rng, b, s, kvh, hd))
    kv_len = jnp.asarray([5, 0, 16, 0], jnp.int32)
    q_pos = jnp.maximum(kv_len - 1, 0)[:, None]
    with policy.apply(impl={"attention": "pallas"}):
        out = np.asarray(common.attention(
            q, k, v, q_pos, jnp.arange(s, dtype=jnp.int32), causal=True,
            kv_len=kv_len))
    assert np.all(out[1] == 0.0) and np.all(out[3] == 0.0)
    assert np.all(np.isfinite(out)) and np.any(out[0] != 0.0)


def test_linearkv_int8_scales_match_dequant_oracle():
    """Int8 LinearKV decode through the kernel's in-block dequant at ragged
    per-row depths equals the scalar oracle over the up-front-dequantized
    slab."""
    rng = np.random.default_rng(2)
    b, s, h, kvh, hd = 4, 32, 4, 2, 64
    kf = _rng_kv(rng, b, s, kvh, hd)
    vf = _rng_kv(rng, b, s, kvh, hd)
    k_scale = np.abs(kf).max(axis=(1, 3)) / 127.0         # (b, kvh)
    v_scale = np.abs(vf).max(axis=(1, 3)) / 127.0
    k8 = np.clip(np.round(kf / k_scale[:, None, :, None]), -127, 127)
    v8 = np.clip(np.round(vf / v_scale[:, None, :, None]), -127, 127)
    pos = np.asarray([31, 3, 17, 0], np.int32)            # ragged depths
    q = rng.standard_normal((b, 1, h, hd)).astype(np.float32)
    with policy.apply(impl={"attention": "pallas"}):
        out = np.asarray(common.attention(
            jnp.asarray(q), jnp.asarray(k8, jnp.int8),
            jnp.asarray(v8, jnp.int8), jnp.asarray(pos)[:, None],
            jnp.arange(s, dtype=jnp.int32), causal=True,
            k_scale=jnp.asarray(k_scale, jnp.float32),
            v_scale=jnp.asarray(v_scale, jnp.float32)))
    kd = k8 * k_scale[:, None, :, None]
    vd = v8 * v_scale[:, None, :, None]
    for row in range(b):
        n = pos[row] + 1
        want = _scalar_oracle(q[row, 0], kd[row, :n], vd[row, :n])
        np.testing.assert_allclose(out[row, 0], want, rtol=2e-4, atol=2e-5,
                                   err_msg=f"row {row}")


def test_ring_write_places_and_wraps():
    """ring_write lands position ``p`` in slot ``p % C`` for per-row
    offsets, and an over-capacity write keeps exactly the last C tokens."""
    b, cap, kvh, hd = 3, 8, 1, 4
    slab = jnp.zeros((b, cap, kvh, hd))
    s = 5
    new = jnp.arange(b * s * kvh * hd, dtype=jnp.float32).reshape(
        b, s, kvh, hd)
    wa = jnp.asarray([0, 6, 13], jnp.int32)  # linear, wrapping, wrapped
    got = np.asarray(dcache.ring_write(slab, new, wa))
    for row, w in enumerate([0, 6, 13]):
        for j in range(s):
            np.testing.assert_array_equal(got[row, (w + j) % cap],
                                          np.asarray(new)[row, j])
    # s >= C: only the last C tokens survive, at their true slots
    big = jnp.arange(b * 11 * kvh * hd, dtype=jnp.float32).reshape(
        b, 11, kvh, hd)
    got = np.asarray(dcache.ring_write(slab, big, jnp.zeros((b,), jnp.int32)))
    for j in range(11 - cap, 11):
        np.testing.assert_array_equal(got[0, j % cap], np.asarray(big)[0, j])


def test_ringkv_slot_positions_and_attend_lens():
    kv = dcache.RingKV(k=jnp.zeros((2, 4, 1, 2)), v=jnp.zeros((2, 4, 1, 2)),
                       pos=jnp.asarray([5, 1], jnp.int32), b_axis=0)
    sp = np.asarray(kv.slot_positions(kv.pos))
    np.testing.assert_array_equal(sp[0], [4, 5, 2, 3])
    big = 1 << 30
    np.testing.assert_array_equal(sp[1], [0, 1, big, big])
    np.testing.assert_array_equal(np.asarray(kv.attend_lens(kv.pos)), [4, 2])


# -- snapshot/restore: the fault-recovery row pair ----------------------------

def _rand_composite(rng, b=3):
    """One composite cache exercising every layout: quantized LinearKV with
    a layer lead axis, RingKV with row 1 at a WRAPPED cursor (pos 13 >
    capacity 8), frozen CrossKV, and StateCarry with mixed validity."""
    def f(*s):
        return jnp.asarray(rng.standard_normal(s).astype(np.float32))
    return {
        "attn": dcache.LinearKV(k=f(2, b, 16, 2, 4), v=f(2, b, 16, 2, 4),
                                pos=jnp.asarray([3, 9, 16], jnp.int32),
                                k_scale=f(2, b, 2), v_scale=f(2, b, 2),
                                b_axis=1),
        "win": dcache.RingKV(k=f(b, 8, 1, 4), v=f(b, 8, 1, 4),
                             pos=jnp.asarray([2, 13, 8], jnp.int32),
                             b_axis=0),
        "cross": dcache.CrossKV(k=f(b, 6, 1, 4), v=f(b, 6, 1, 4), b_axis=0),
        "ssm": dcache.StateCarry(states={"h": f(2, b, 5),
                                         "conv": f(1, b, 3, 4)},
                                 valid=jnp.asarray([True, False, True])),
    }


def _assert_tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_snapshot_restore_row_roundtrip_all_layouts():
    """snapshot_row/restore_row carry EVERY per-row fact — k/v slabs, write
    cursors (including a wrapped ring cursor), int8 scales, frozen cross-KV,
    recurrent state and its validity flag — and touch only their row: after
    corrupting row 1 wholesale, restoring its snapshot reproduces the
    original composite leaf-for-leaf."""
    cache = _rand_composite(np.random.default_rng(5))
    snap = dcache.snapshot_row(cache, 1)
    # host-staged: numpy leaves, no live device buffers in the resume point
    assert all(isinstance(x, np.ndarray) for x in jax.tree.leaves(snap))
    assert int(snap["win"].pos[0]) == 13          # wrapped absolute cursor
    corrupt = dcache.set_slot(cache, 1,
                              jax.tree.map(jnp.zeros_like, snap))
    assert not np.array_equal(np.asarray(corrupt["attn"].k),
                              np.asarray(cache["attn"].k))
    _assert_tree_equal(dcache.restore_row(corrupt, 1, snap), cache)


def test_snapshot_restores_into_different_slot():
    """Row slices carry no slot identity: a snapshot of slot 1 restored
    into slot 0 of a fresh cache reproduces slot 1's state there (the
    engine re-admits recovered requests into whichever slot matches)."""
    rng = np.random.default_rng(6)
    cache = _rand_composite(rng)
    snap = dcache.snapshot_row(cache, 1)
    fresh = jax.tree.map(jnp.zeros_like, _rand_composite(rng))
    moved = dcache.restore_row(fresh, 0, snap)
    _assert_tree_equal(dcache.slot(moved, 0), dcache.slot(cache, 1))
    # the other rows of the fresh cache stay zero
    _assert_tree_equal(dcache.slot(moved, 2),
                       jax.tree.map(jnp.zeros_like, dcache.slot(cache, 2)))


def test_snapshot_compatible_gates_cross_replica_restore():
    """The cross-replica portability gate the router leans on: a snapshot
    restores into any same-config cache (accepted silently, eval_shape
    only), while a different sequence capacity, a different KV dtype, or a
    missing layout fails loudly with the mismatch named — never a corrupt
    row."""
    rng = np.random.default_rng(7)
    cache = _rand_composite(rng)
    snap = dcache.snapshot_row(cache, 1)
    dcache.snapshot_compatible(cache, snap)     # same config: no raise
    # shorter sequence axis, as from a replica built with a smaller max_len
    short = jax.tree.map(
        lambda x: x[:, :-1] if np.ndim(x) >= 2 and x.shape[1] > 1 else x,
        snap)
    with pytest.raises(ValueError, match="shape"):
        dcache.snapshot_compatible(cache, short)
    # quantization mismatch: f32 snapshot leaves downcast to f16
    half = jax.tree.map(
        lambda x: np.asarray(x, np.float16)
        if np.asarray(x).dtype == np.float32 else x, snap)
    with pytest.raises(ValueError, match="dtype"):
        dcache.snapshot_compatible(cache, half)
    # structural mismatch: a layout missing from the composite
    with pytest.raises(ValueError, match="layout"):
        dcache.snapshot_compatible(
            cache, {k: v for k, v in snap.items() if k != "attn"})


# -- layering: slab mutation stays inside repro.models.cache ------------------

_FORBIDDEN = [
    (r"dynamic_update_slice", "raw dynamic_update_slice on cache slabs"),
    (r"jnp\.roll", "ring maintenance must use cache.ring_write"),
    (r"""["']k["']\s*:""", "raw cache dict entry 'k'"),
    (r"""["']v["']\s*:""", "raw cache dict entry 'v'"),
    (r"""["']xk["']\s*:""", "raw cache dict entry 'xk'"),
    (r"""["']img_k["']\s*:""", "raw cache dict entry 'img_k'"),
]


def test_family_modules_never_mutate_cache_slabs_directly():
    """Every model family goes through the DecodeCache layouts and the
    cache-module write helpers: no family source constructs raw k/v cache
    dict entries or hand-rolls slab writes."""
    from repro.models import dense, encdec, hybrid, ssm, vlm
    for mod in (dense, hybrid, ssm, encdec, vlm):
        src = inspect.getsource(mod)
        for pat, why in _FORBIDDEN:
            hits = [ln + 1 for ln, line in enumerate(src.splitlines())
                    if re.search(pat, line)]
            assert not hits, (
                f"{mod.__name__} line(s) {hits}: {why} — route it through "
                f"repro.models.cache")
