"""Continuous-batching engine: numerics contract + PWS slot scheduling.

The acceptance bar for ``repro.launch.engine``: with greedy decoding the
engine's per-request tokens are IDENTICAL, request-for-request, to running
each request alone through the lockstep jitted path — dense fp32 and
int8-KV, hybrid, and ssm — with batched chunked prefill interleaved
between decode steps, pressure eviction replaying evicted requests
exactly, and the per-row decode step compiling exactly once across ragged
batch compositions.  The SlotScheduler's §4.7 round discipline (bounded
steals per round, non-increasing round priorities, deterministic matching)
is unit-tested without a model.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.kernels import policy
from repro.launch.engine import Engine, SlotScheduler, check_lockstep_parity
from repro.launch.mesh import make_debug_mesh
from repro.launch.serve import Request
from repro.models.base import Model, RunOptions, UnsupportedFamilyError


def _requests(n, *, seed=0, max_prompt=20, max_new=8, vocab=256, align=1):
    """Mixed-length workload: ragged prompts, skewed generation budgets.
    ``align`` rounds prompt lengths up to a multiple (ssm exactness needs
    chunk boundaries on ``cfg.ssm_chunk`` multiples)."""
    rng = np.random.default_rng(seed)

    def plen():
        n_ = int(rng.integers(4, max_prompt))
        return -(-n_ // align) * align

    return [Request(i, rng.integers(3, vocab, plen()).astype(np.int32),
                    max_new=int(rng.integers(2, max_new + 1)))
            for i in range(n)]


@pytest.fixture(scope="module")
def mesh():
    return make_debug_mesh(tp=min(2, len(jax.devices())))


@pytest.fixture(autouse=True)
def _clear_autotune_pin():
    """Server.__init__ runs the launcher's ``autotune.startup``, which pins
    the mode process-wide — clear it so later test modules (test_policy's
    mode-resolution asserts) see the unpinned default again."""
    from repro.kernels import autotune
    yield
    autotune.set_mode(None)


def _run_and_check(mesh, *, chunk, n_requests=6, slots=3,
                   arch="qwen3-1.7b", align=1, budget=None):
    cfg = get_smoke_config(arch)
    engine = Engine(cfg, mesh, max_batch=slots, max_len=64, chunk=chunk,
                    cache_budget=budget, opts=RunOptions())
    reqs = _requests(n_requests, vocab=cfg.vocab_size, align=align)
    out = engine.run(reqs)
    assert check_lockstep_parity(engine, reqs), \
        "engine tokens diverge from the run-alone lockstep baseline"
    return engine, reqs, out


def test_engine_matches_lockstep_fp32(mesh):
    """Chunked prefill (chunk < prompt lengths) + slot reuse across more
    requests than slots: every request's greedy tokens equal its run-alone
    lockstep tokens, and the telemetry accounts for every admission."""
    engine, reqs, out = _run_and_check(mesh, chunk=8)
    tel = out["telemetry"]
    assert tel["matches"] == len(reqs)      # every request admitted once
    assert tel["evictions"] == len(reqs)    # ... and released once
    assert tel["max_round_matches"] <= engine.scheduler.p - 1
    assert out["completed"] == {r.uid: len(r.out) for r in reqs}
    assert all(len(r.out) == r.max_new for r in reqs)  # no EOS in workload


def test_engine_matches_lockstep_int8_kv(mesh):
    """The int8 KV-cache variant under the pallas attention policy: per-row
    scale composition in the kernel keeps the parity contract.  chunk covers
    the longest prompt so each request calibrates its scales on the same
    (whole-prompt) first chunk the lockstep baseline uses."""
    with policy.apply(impl={"attention": "pallas"},
                      variants={"attention": {"kv_dtype": "int8"}}):
        _run_and_check(mesh, chunk=24, n_requests=4)


def test_engine_matches_lockstep_hybrid(mesh):
    """The hybrid family through the SAME engine loop: LRU/conv state rows
    park under identity updates (a=1, b=0) while neighbours prefill.  chunk
    covers the longest prompt — the LRU h0-fold reassociates across chunk
    boundaries, so single-chunk prefill is the fp-exact arm."""
    _run_and_check(mesh, chunk=24, n_requests=4, arch="recurrentgemma-2b")


def test_engine_matches_lockstep_ssm(mesh):
    """The ssm family through the engine: SSD state is chunk-exact when
    prompt and chunk lengths sit on ``cfg.ssm_chunk`` (= 8) multiples, so
    aligned prompts decode token-identical to the run-alone baseline."""
    _run_and_check(mesh, chunk=16, n_requests=4, arch="mamba2-370m",
                   align=8)


def test_engine_pressure_eviction_requeues_and_finishes(mesh):
    """Eviction under memory pressure: a context budget below the
    workload's working set forces >= 1 eviction; the evicted request
    re-queues through match_round, replays its generated tokens inside the
    re-prefilled prompt, and every request still finishes with its exact
    lockstep tokens."""
    engine, reqs, out = _run_and_check(mesh, chunk=8, budget=40)
    tel = out["telemetry"]
    assert tel["pressure_evictions"] >= 1
    assert tel["matches"] == len(reqs) + tel["pressure_evictions"]
    assert tel["evictions"] == len(reqs)  # completion releases only
    assert all(len(r.out) == r.max_new for r in reqs)


def test_engine_batched_prefill_shares_launches(mesh):
    """Batched chunked prefill: with more fresh admissions than one, a
    single padded chunk launch serves >= 2 prefilling slots (chunk-rows
    strictly exceed launches)."""
    _, _, out = _run_and_check(mesh, chunk=8)
    assert out["prefill_chunk_rows"] > out["prefill_chunks"]


def test_engine_unsupported_family_is_structured(mesh, monkeypatch):
    """A model stripped of a serving-contract method fails Engine
    construction with UnsupportedFamilyError carrying the family and the
    missing method name — not an attribute error mid-serve."""
    from repro.models import dense as dense_mod
    monkeypatch.setattr(dense_mod.DenseLM, "prefill_chunk",
                        Model.prefill_chunk)
    cfg = get_smoke_config("qwen3-1.7b")
    with pytest.raises(UnsupportedFamilyError) as ei:
        Engine(cfg, mesh, max_batch=2, max_len=32, chunk=8,
               opts=RunOptions())
    assert ei.value.family == "dense"
    assert ei.value.missing == "prefill_chunk"


def test_engine_decode_compiles_once(mesh):
    """The no-recompile acceptance check: per-row positions are traced
    vectors, so one compilation of the batched decode step serves every
    ragged composition of slot depths the run produces."""
    engine, _, out = _run_and_check(mesh, chunk=8)
    assert out["decode_steps"] > 1
    assert engine.stats()["decode_compilations"] == 1


# -- eviction policy + structured stats ---------------------------------------

@pytest.mark.parametrize("evict", ["largest", "coldest"])
def test_engine_evict_policy_replays_exactly(mesh, evict):
    """Both pressure-eviction policies — ``largest`` (most cache rows) and
    ``coldest`` (stalest ``last_step`` stamp) — replay the evicted request
    token-exactly: the victim choice is a scheduling decision, never a
    numerics one."""
    cfg = get_smoke_config("qwen3-1.7b")
    engine = Engine(cfg, mesh, max_batch=3, max_len=64, chunk=8,
                    cache_budget=40, evict_policy=evict, opts=RunOptions())
    reqs = _requests(6, vocab=cfg.vocab_size)
    out = engine.run(reqs)
    assert out["telemetry"]["pressure_evictions"] >= 1
    assert check_lockstep_parity(engine, reqs)
    assert all(len(r.out) == r.max_new for r in reqs)


def test_engine_evict_policy_validated(mesh):
    cfg = get_smoke_config("qwen3-1.7b")
    with pytest.raises(ValueError):
        Engine(cfg, mesh, max_batch=2, max_len=32, chunk=8,
               evict_policy="newest", opts=RunOptions())


def test_engine_stats_structure(mesh):
    """``Engine.stats()`` is the public telemetry surface: consumers (the
    router, benchmarks, these tests) read it instead of private fields.
    The occupancy slices tile max_batch, fault counters carry every PR-9
    key, and the scheduler slice excludes them (no double counting)."""
    from repro.runtime import FAULT_COUNTER_KEYS
    engine, reqs, out = _run_and_check(mesh, chunk=8)
    stats = engine.stats()
    assert stats is not out["stats"]            # fresh dict per call
    occ = stats["occupancy"]
    assert occ["prefilling"] + occ["decoding"] + occ["free"] == \
        engine.max_batch
    assert occ["queued"] == 0 and stats["work_remaining"] == 0  # drained
    assert set(FAULT_COUNTER_KEYS) <= set(stats["faults"])
    assert not set(FAULT_COUNTER_KEYS) & set(stats["scheduler"])
    assert stats["launches"]["decode"] > 0 and stats["busy_s"] > 0
    assert stats["decode_compilations"] == 1
    deg = stats["degradation"]
    assert deg["active_limit"] == deg["max_batch"] == engine.max_batch


# -- SlotScheduler (no model) -------------------------------------------------

def _wr(r):
    return len(r.prompt) + r.max_new - len(r.out)


def test_scheduler_bounded_steals_per_round():
    """Obs. 4.3 at the engine: a round matches at most p - 1 requests of
    the round's priority, even with p idle slots and a deep queue."""
    sched = SlotScheduler(4)
    queue = [Request(i, np.zeros(8, np.int32), max_new=4) for i in range(10)]
    got = sched.assign([0, 1, 2, 3], queue, _wr)
    # all 10 share one priority: rounds of <= 3 until the idle supply drains
    assert len(got) == 4
    assert sched.counters["max_round_matches"] <= 3
    assert sched.counters["rounds"] >= 2


def test_scheduler_priority_order_and_determinism():
    """Largest work-remaining first (the size-based order), idle slots by
    rank; the same inputs always produce the same assignment."""
    sched = SlotScheduler(3)
    queue = [Request(0, np.zeros(4, np.int32), max_new=2),    # work 6
             Request(1, np.zeros(16, np.int32), max_new=8),   # work 24
             Request(2, np.zeros(8, np.int32), max_new=4)]    # work 12
    got = sched.assign([2, 0], queue, _wr)
    assert got == [(0, 1), (2, 2)]  # slot 0 takes the biggest task
    again = SlotScheduler(3).assign([2, 0], queue, _wr)
    assert got == again


def test_scheduler_counters_accumulate():
    sched = SlotScheduler(2)
    queue = [Request(i, np.zeros(4, np.int32), max_new=2) for i in range(3)]
    a = sched.assign([0, 1], queue, _wr)
    assert len(a) == 2 and sched.counters["matches"] == 2
    b = sched.assign([1], queue, _wr)
    assert len(b) == 1 and sched.counters["matches"] == 3
    assert sched.assign([0], [], _wr) == []  # empty queue: no round runs
