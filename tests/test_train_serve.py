"""Integration: end-to-end training (loss decreases; checkpoint/restart is
bit-deterministic), serving (prefill+decode loop), planner/dry-run machinery
on the real single-device backend."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_smoke_config
from repro.core import planner
from repro.data import DataConfig
from repro.launch.mesh import make_debug_mesh
from repro.launch.train import train
from repro.models.base import RunOptions


def small_mesh():
    return make_debug_mesh(1, tp=1)


@pytest.mark.slow
def test_training_loss_decreases(tmp_path):
    cfg = get_smoke_config("qwen3-1.7b")
    out = train(cfg, mesh=small_mesh(), steps=15,
                data_cfg=DataConfig(global_batch=4, seq_len=64),
                opts=RunOptions(remat="none"), log_every=0)
    first = np.mean(out["losses"][:3])
    last = np.mean(out["losses"][-3:])
    assert last < first, (first, last)


@pytest.mark.slow
def test_checkpoint_restart_is_deterministic(tmp_path):
    cfg = get_smoke_config("qwen3-1.7b")
    data_cfg = DataConfig(global_batch=2, seq_len=32, seed=5)
    kw = dict(mesh=small_mesh(), data_cfg=data_cfg, opts=RunOptions(remat="none"),
              log_every=0)

    # uninterrupted run
    full = train(cfg, steps=8, **kw)

    # interrupted: 4 steps + checkpoint, then resume to 8
    d = tmp_path / "ck"
    part1 = train(cfg, steps=4, ckpt_dir=str(d), save_every=4, **kw)
    part2 = train(cfg, steps=8, ckpt_dir=str(d), save_every=100, **kw)

    np.testing.assert_allclose(part2["losses"], full["losses"][4:], rtol=1e-5)


def test_serving_loop():
    from repro.launch.serve import Request, Server

    cfg = dataclasses.replace(get_smoke_config("qwen3-1.7b"), dtype="float32")
    server = Server(cfg, small_mesh(), max_len=64, opts=RunOptions(remat="none"))
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(3, cfg.vocab_size, 8).astype(np.int32), max_new=4)
            for i in range(2)]
    out = server.run_batch(reqs)
    assert out["tokens"] == 8
    assert all(len(r.out) == 4 for r in reqs)


@pytest.mark.slow
def test_greedy_decode_matches_teacher_forcing():
    """Serving correctness: tokens produced by the decode loop equal argmax
    of teacher-forced prefill logits at each step."""
    cfg = dataclasses.replace(get_smoke_config("qwen3-1.7b"), dtype="float32")
    from repro.models import build_model

    model = build_model(cfg, RunOptions(remat="none"))
    params = model.init(jax.random.key(0))
    prompt = jax.random.randint(jax.random.key(1), (1, 6), 3, cfg.vocab_size)
    max_len = 32

    # decode loop
    logits, cache = model.prefill(params, {"tokens": prompt}, max_len)
    produced = []
    cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for i in range(3):
        produced.append(int(cur[0, 0]))
        logits, cache = model.decode_step(params, cur, jnp.int32(6 + i), cache)
        cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)

    # teacher forcing with the produced tokens
    toks = jnp.concatenate([prompt, jnp.asarray([produced], jnp.int32)], axis=1)
    for i in range(3):
        lg, _ = model.prefill(params, {"tokens": toks[:, : 6 + i]}, max_len)
        assert int(jnp.argmax(lg, -1)[0]) == produced[i], i


# -- planner ---------------------------------------------------------------------

def test_planner_specs_divisible():
    """Every sharded dim must be divisible by its mesh axes (the balance
    condition as a hard planner invariant)."""
    import os

    from repro.launch.steps import abstract_params, build_step_bundle
    from repro.models import build_model

    cfg = get_smoke_config("qwen3-moe-30b-a3b")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    model = build_model(cfg)
    ap = abstract_params(model)
    specs = planner.plan_params(ap, mesh)

    def check(leaf, spec):
        for dim, entry in zip(leaf.shape, spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            assert dim % size == 0

    jax.tree.map(check, ap, specs,
                 is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))


def test_hlo_analysis_counts_scan_flops():
    """The analyzer's raison d'être: flops inside lax.scan bodies are
    trip-count multiplied (cost_analysis undercounts them)."""
    from repro.launch.hlo_analysis import analyze

    def f(w, x):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=6)
        return h.sum()

    w = jnp.ones((64, 64))
    x = jnp.ones((4, 64))
    txt = jax.jit(f).lower(w, x).compile().as_text()
    stats = analyze(txt)
    want = 2 * 4 * 64 * 64 * 6  # 6 iterations
    assert stats.flops >= want * 0.9, (stats.flops, want)


def test_shape_bytes_parsing():
    from repro.launch.hlo_analysis import shape_bytes

    assert shape_bytes("f32[2,3]{1,0}") == 24
    assert shape_bytes("bf16[4]") == 8
    assert shape_bytes("(f32[2], s32[3])") == 20
    assert shape_bytes("pred[]") == 1
