"""Value-level correctness of the paper's algorithms vs independent oracles."""
import jax
import jax.numpy as jnp
import networkx as nx
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip cleanly when absent
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import algorithms_jax as A


@given(st.integers(1, 500), st.integers(1, 7))
@settings(max_examples=25, deadline=None)
def test_prefix_sums_property(n, blk_pow):
    x = jnp.asarray(np.random.default_rng(n).standard_normal(n), jnp.float32)
    out = A.prefix_sums(x, block=2 ** blk_pow)
    np.testing.assert_allclose(out, jnp.cumsum(x), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n", [2, 8, 64])
def test_bi_roundtrip_and_transpose(n):
    m = jnp.asarray(np.random.default_rng(0).standard_normal((n, n)), jnp.float32)
    flat = A.rm_to_bi(m)
    np.testing.assert_array_equal(A.bi_to_rm(flat, n), m)
    np.testing.assert_array_equal(A.bi_to_rm_gapped(flat, n), m)
    np.testing.assert_array_equal(A.bi_to_rm(A.mt_bi(flat, n), n), m.T)


@pytest.mark.parametrize("n,leaf", [(64, 16), (128, 32)])
def test_strassen(n, leaf):
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
    np.testing.assert_allclose(A.strassen(a, b, leaf), a @ b, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("n", [16, 256, 1024])
def test_fft_six_step(n):
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal(n) + 1j * rng.standard_normal(n))
    np.testing.assert_allclose(A.fft_six_step(x), jnp.fft.fft(x), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n,seed", [(100, 0), (1000, 1), (4096, 2)])
def test_list_ranking(n, seed):
    perm = np.random.default_rng(seed).permutation(n)
    succ = np.empty(n, dtype=np.int64)
    succ[perm[:-1]] = perm[1:]
    succ[perm[-1]] = perm[-1]
    np.testing.assert_array_equal(A.list_ranking(succ), A.list_ranking_oracle(succ))


@pytest.mark.parametrize("n,m,seed", [(50, 30, 0), (300, 200, 1), (500, 700, 2)])
def test_connected_components(n, m, seed):
    g = nx.gnm_random_graph(n, m, seed=seed)
    edges = np.array(list(g.edges()), dtype=np.int64).reshape(-1, 2)
    lab = A.connected_components(n, edges)
    comps = list(nx.connected_components(g))
    for comp in comps:
        assert len(set(lab[list(comp)])) == 1
    reps = [lab[min(c)] for c in comps]
    assert len(set(reps)) == len(comps)
