"""Kernel substrate: Morton codec round-trips (cross-validated against
``core.layouts``), planner tile choices across dtypes and odd shapes, and
registry-vs-oracle parity for every registered op (including the FFT)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import costmodel, layouts
from repro.kernels import morton, planner, registry


# -- Morton codec ------------------------------------------------------------

@pytest.mark.parametrize("i,j", [(0, 0), (1, 0), (0, 1), (5, 9), (255, 255),
                                 (2**15 - 1, 2**15 - 1), (12345, 54321)])
def test_morton_roundtrip(i, j):
    g = morton.morton_of(i, j)
    ii, jj = morton.morton_ij(g)
    assert (ii, jj) == (i, j)


def test_morton_matches_core_layouts():
    """The kernel-side integer codec and the simulator's numpy codec are the
    same function."""
    rng = np.random.default_rng(0)
    r = rng.integers(0, 2**15, 64)
    c = rng.integers(0, 2**15, 64)
    want = layouts.bi_index(r, c)
    got = np.asarray([morton.morton_of(int(a), int(b)) for a, b in zip(r, c)])
    np.testing.assert_array_equal(got, want.astype(np.int64))
    rr, cc = layouts.bi_coords(want)
    for z, a, b in zip(want, rr, cc):
        assert morton.morton_ij(int(z)) == (int(a), int(b))


def test_morton_roundtrip_traced():
    """The codec must survive jit (it runs on traced Pallas grid indices)."""
    g = jnp.arange(64, dtype=jnp.int32)
    i, j = jax.jit(morton.morton_ij)(g)
    back = jax.jit(morton.morton_of)(i, j)
    np.testing.assert_array_equal(np.asarray(back), np.arange(64))


@pytest.mark.parametrize("nm,nn,is_morton", [
    (8, 8, True), (1, 1, True), (4, 8, False), (8, 4, False),
    (6, 6, False), (3, 5, False),
])
def test_grid_decode_bijective(nm, nn, is_morton):
    """Morton on square power-of-two grids, row-major fallback otherwise —
    either way every tile is visited exactly once."""
    assert morton.supports_morton(nm, nn) == is_morton
    decode = morton.grid_decode(nm, nn)
    seen = {tuple(int(v) for v in decode(g)) for g in range(nm * nn)}
    assert seen == {(i, j) for i in range(nm) for j in range(nn)}


def test_grid_decode_morton_order_is_quadrant_recursive():
    decode = morton.grid_decode(4, 4)
    order = [tuple(int(v) for v in decode(g)) for g in range(16)]
    # first quarter of the schedule = top-left quadrant (recursively BI)
    assert set(order[:4]) == {(0, 0), (0, 1), (1, 0), (1, 1)}
    assert set(order[12:]) == {(2, 2), (2, 3), (3, 2), (3, 3)}


# -- planner -----------------------------------------------------------------

DP = planner.DeviceParams(platform="cpu", kind="test", fast_bytes=8 * 2**20,
                          line_bytes=64)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "int8"])
@pytest.mark.parametrize("m,k,n", [(512, 512, 512), (384, 96, 768),
                                   (100, 60, 84), (1, 7, 13)])
def test_plan_matmul_tiles_divide_and_fit(m, k, n, dtype):
    plan = planner.plan_matmul(m, k, n, dtype, DP)
    bm, bn, bk = plan["bm"], plan["bn"], plan["bk"]
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    itemsize = jnp.dtype(dtype).itemsize
    working = (bm * bk + bk * bn) * itemsize + 4 * bm * bn
    assert working <= DP.fast_bytes  # tiles fit the queried fast memory


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("n", [256, 8192, 96, 10])
def test_plan_scan_block_divides(n, dtype):
    block = planner.plan_scan((4, n), dtype, DP)["block"]
    assert n % block == 0
    assert block * jnp.dtype(dtype).itemsize * 4 <= DP.fast_bytes


@pytest.mark.parametrize("m,n", [(512, 512), (512, 256), (100, 60), (64, 1)])
def test_plan_transpose_tile_divides_both(m, n):
    bt = planner.plan_transpose(m, n, "float32", DP)["bt"]
    assert m % min(bt, m) == 0 and n % min(bt, n) == 0


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("sq,sk,hd", [(512, 512, 64), (384, 384, 80),
                                      (64, 2048, 128), (1, 1, 64)])
def test_plan_attention_blocks_divide(sq, sk, hd, dtype):
    plan = planner.plan_attention(sq, sk, hd, dtype, DP)
    assert sq % plan["q_block"] == 0 and sk % plan["kv_block"] == 0


def test_planner_scales_with_fast_memory():
    """Resource-obliviousness: a bigger queried M yields bigger (or equal)
    tiles, without any kernel-side change."""
    small = planner.DeviceParams("cpu", "s", 2**20, 64)
    big = planner.DeviceParams("cpu", "b", 2**26, 64)
    n = 1 << 14
    p_small = planner.plan_matmul(n, n, n, "float32", small)
    p_big = planner.plan_matmul(n, n, n, "float32", big)
    assert p_big["bm"] >= p_small["bm"] * 4  # 64x memory -> ~8x edge


def test_plan_matmul_traffic_within_envelope():
    """The planned tiling's modeled line traffic lands inside a constant
    factor of the costmodel's sequential cache-complexity envelope."""
    n = 2048
    plan = planner.plan_matmul(n, n, n, "float32", DP)
    got = planner.modeled_matmul_misses(n, n, n, "float32", plan, DP)
    envelope = costmodel.seq_cache_complexity_mm(
        n, n, n, DP.fast_bytes // 4, DP.line_bytes // 4)
    assert got <= 4.0 * envelope, (got, envelope)


def test_resolve_run_options_fills_planner_fields():
    from repro.models.base import RunOptions

    opts = planner.resolve_run_options(RunOptions())
    assert opts.q_block is not None and opts.kv_block is not None
    # explicit values survive
    pinned = planner.resolve_run_options(RunOptions(q_block=64, kv_block=128))
    assert (pinned.q_block, pinned.kv_block) == (64, 128)


# -- registry ----------------------------------------------------------------

def test_registry_lists_the_paper_trio_plus_attention():
    assert registry.names() == ["attention", "fft", "matmul", "scan",
                                "transpose"]


def test_registry_unknown_op():
    with pytest.raises(KeyError, match="unknown kernel"):
        registry.get("conv")


def _case(name):
    key = jax.random.key
    if name == "scan":
        return (jax.random.normal(key(0), (3, 512)),), {}
    if name == "matmul":
        return (jax.random.normal(key(1), (128, 96)),
                jax.random.normal(key(2), (96, 256))), {}
    if name == "transpose":
        return (jax.random.normal(key(3), (128, 256)),), {}
    if name == "attention":
        return (jax.random.normal(key(4), (2, 256, 64)),
                jax.random.normal(key(5), (2, 256, 64)),
                jax.random.normal(key(6), (2, 256, 64))), {
                    "causal": True, "window": 0}
    if name == "fft":
        x = (jax.random.normal(key(7), (2, 256))
             + 1j * jax.random.normal(key(8), (2, 256)))
        return (x.astype(jnp.complex64),), {}
    raise AssertionError(name)


@pytest.mark.parametrize("name", ["scan", "matmul", "transpose", "attention",
                                  "fft"])
def test_registry_pallas_matches_oracle(name):
    """The generic dispatch path: planner-tiled Pallas (interpret) vs the
    ref.py oracle, for every registered op."""
    args, kwargs = _case(name)
    got = registry.dispatch(name, *args, impl="pallas", **kwargs)
    want = registry.dispatch(name, *args, impl="ref", **kwargs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_registry_tile_overrides_win():
    x = jax.random.normal(jax.random.key(0), (2, 256))
    got = registry.dispatch("scan", x, impl="pallas", block=64)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(registry.dispatch("scan", x)),
                               rtol=1e-4, atol=1e-4)
    # the override must actually reach the kernel: a non-divisor block trips
    # bp_scan's divisibility assert (a silently dropped override would not)
    with pytest.raises(AssertionError):
        registry.dispatch("scan", x, impl="pallas", block=60)


def test_registry_resolve_matches_backend():
    """The generic resolver's 'auto' expansion follows supported(); ops
    without a registered backward never resolve pallas for (default)
    differentiable callers, even when forced."""
    from repro.kernels import policy

    want = "pallas" if jax.default_backend() == "tpu" else "jnp"
    assert registry.resolve("attention") == want
    with policy.apply(impl={"*": "pallas"}):
        assert registry.resolve("attention") == "pallas"
        assert registry.resolve("scan") == "jnp"  # no VJP: model callers -> jnp
        assert registry.resolve("scan", differentiable=False) == "pallas"


def test_fft_nonsquare_split_and_odd_rows():
    """Non-power-of-two split request degrades gracefully; non-square
    (rows != n) batches work."""
    x = (jax.random.normal(jax.random.key(0), (3, 128))
         + 1j * jax.random.normal(jax.random.key(1), (3, 128))).astype(jnp.complex64)
    for n1 in (1, 4, 8, 128, 100):  # 100 does not divide 128 -> snaps down
        got = registry.dispatch("fft", x, impl="pallas", n1=n1)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(jnp.fft.fft(x, axis=-1)),
                                   rtol=2e-3, atol=2e-3)
    with pytest.raises(ValueError, match="power-of-two"):
        registry.dispatch("fft", jnp.zeros((2, 96), jnp.complex64),
                          impl="pallas")


def test_flash_attention_morton_grid_matches_rowmajor_shapes():
    """bh == nq square power-of-two grid (Morton) and a ragged grid
    (row-major fallback) both match the oracle."""
    from repro.kernels import flash_attention, ref

    for bh, s, qb in [(4, 256, 64), (3, 256, 64)]:  # nq=4 -> square / ragged
        q = jax.random.normal(jax.random.key(1), (bh, s, 32))
        k = jax.random.normal(jax.random.key(2), (bh, s, 32))
        v = jax.random.normal(jax.random.key(3), (bh, s, 32))
        out = flash_attention(q, k, v, causal=True, q_block=qb, kv_block=qb)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)
