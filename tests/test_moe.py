"""MoE gapped dispatch: sort vs one-hot oracle, grouping invariance,
capacity/gapping properties, drop behavior."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip cleanly when absent
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.models.moe_layer import (
    SUBLANE,
    gapped_capacity,
    moe_ffn_onehot,
    moe_ffn_sort,
    router,
)


def make(N=64, d=16, E=8, f=32, seed=0):
    ks = jax.random.split(jax.random.key(seed), 5)
    return (
        jax.random.normal(ks[0], (N, d), jnp.float32),
        jax.random.normal(ks[1], (d, E), jnp.float32),
        jax.random.normal(ks[2], (E, d, f), jnp.float32) * 0.1,
        jax.random.normal(ks[3], (E, d, f), jnp.float32) * 0.1,
        jax.random.normal(ks[4], (E, f, d), jnp.float32) * 0.1,
    )


@given(st.integers(0, 5))
@settings(max_examples=5, deadline=None)
def test_sort_matches_onehot_without_drops(seed):
    x, wr, eg, eu, ed = make(seed=seed)
    y1, a1 = moe_ffn_sort(x, wr, eg, eu, ed, k=2, capacity_factor=8.0)
    y2, a2 = moe_ffn_onehot(x, wr, eg, eu, ed, k=2, capacity_factor=8.0)
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(a1, a2, rtol=1e-5)


@pytest.mark.parametrize("groups", [1, 2, 4, 8])
def test_grouping_invariance_ample_capacity(groups):
    x, wr, eg, eu, ed = make()
    y1, _ = moe_ffn_sort(x, wr, eg, eu, ed, k=2, capacity_factor=8.0, n_groups=1)
    yg, _ = moe_ffn_sort(x, wr, eg, eu, ed, k=2, capacity_factor=8.0, n_groups=groups)
    np.testing.assert_allclose(yg, y1, rtol=1e-5, atol=1e-5)


def test_gapped_capacity_is_sublane_aligned():
    for n, e, k, cf in [(1000, 8, 2, 1.25), (64, 64, 8, 1.0), (7, 3, 1, 1.0)]:
        c = gapped_capacity(n, e, k, cf)
        assert c % SUBLANE == 0 and c >= SUBLANE


def test_drops_under_tight_capacity():
    """With capacity_factor ~0, most tokens drop -> output ~0 (never NaN)."""
    x, wr, eg, eu, ed = make()
    y, _ = moe_ffn_sort(x, wr, eg, eu, ed, k=2, capacity_factor=0.01)
    assert bool(jnp.all(jnp.isfinite(y)))
    y_full, _ = moe_ffn_sort(x, wr, eg, eu, ed, k=2, capacity_factor=8.0)
    assert float(jnp.sum(jnp.abs(y))) < float(jnp.sum(jnp.abs(y_full)))


def test_router_normalizes_topk():
    x, wr, *_ = make()
    p, e, aux = router(x, wr, 3)
    np.testing.assert_allclose(np.asarray(jnp.sum(p, -1)), 1.0, rtol=1e-5)
    assert float(aux) >= 1.0 - 1e-5  # aux >= 1 with equality iff perfectly balanced


def test_gradients_flow_through_dispatch():
    x, wr, eg, eu, ed = make()

    def loss(x, eg):
        y, aux = moe_ffn_sort(x, wr, eg, eu, ed, k=2, capacity_factor=2.0, n_groups=2)
        return jnp.sum(y * y) + 0.01 * aux

    gx, ge = jax.grad(loss, argnums=(0, 1))(x, eg)
    assert bool(jnp.all(jnp.isfinite(gx))) and float(jnp.sum(jnp.abs(ge))) > 0
