"""Ambient ExecutionPolicy: the one dispatch-resolution API.

Covers the policy value object (wildcard precedence, functional update),
the context stack (nesting, restore-on-exit, exception unwind, thread and
jit-trace safety), environment assembly (``REPRO_IMPL`` grammar,
``REPRO_STRICT_TILES``, ``REPRO_INTERPRET``), the generic resolver's
capability gates, variant overrides flowing into dispatch, the RunOptions
compat shim (identical greedy-decode tokens and train-step loss/grads vs
the equivalent explicit policy, dense + hybrid), the scoped ring-buffer
pin, the warn-once reset hook, and the shared kernel/simulator namespace.
"""
import contextlib
import dataclasses
import threading
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.kernels import autotune, policy, registry
from repro.models import build_model
from repro.models.base import RunOptions


# -- the value object ---------------------------------------------------------

def test_wildcard_precedence():
    pol = policy.ExecutionPolicy(impl={"attention": "pallas", "*": "jnp"})
    assert pol.impl_for("attention") == "pallas"  # own entry beats wildcard
    assert pol.impl_for("matmul") == "jnp"        # wildcard covers the rest
    assert policy.ExecutionPolicy().impl_for("matmul") == "auto"  # default


def test_policy_is_frozen_and_validated():
    pol = policy.ExecutionPolicy(impl={"*": "pallas"})
    with pytest.raises(dataclasses.FrozenInstanceError):
        pol.autotune = "search"
    with pytest.raises(TypeError):
        pol.impl["matmul"] = "jnp"  # MappingProxyType: no mutation
    with pytest.raises(ValueError, match="unknown impl"):
        policy.ExecutionPolicy(impl={"matmul": "fancy"})
    with pytest.raises(ValueError, match="unknown autotune"):
        policy.ExecutionPolicy(autotune="always")
    # programmatic typos must not silently no-op either (cf. parse_impl_arg)
    with pytest.raises(ValueError, match="unknown op"):
        policy.ExecutionPolicy(impl={"atention": "jnp"})
    with pytest.raises(ValueError, match="unknown op"):
        with policy.apply(variants={"matmull": {"backend": "classical"}}):
            pass


def test_with_merges_impl_entries():
    pol = policy.ExecutionPolicy(impl={"*": "jnp", "attention": "pallas"})
    new = pol.with_(impl={"matmul": "pallas"}, autotune="replay")
    assert new.impl_for("attention") == "pallas"  # kept
    assert new.impl_for("matmul") == "pallas"     # merged in
    assert new.impl_for("scan") == "jnp"          # wildcard kept
    assert new.autotune == "replay" and pol.autotune is None  # original intact


# -- the stack ----------------------------------------------------------------

def test_apply_nesting_and_restore_on_exit():
    base = policy.current()
    assert base.impl_for("matmul") == "auto"
    with policy.apply(impl={"matmul": "pallas"}):
        assert policy.current().impl_for("matmul") == "pallas"
        with policy.apply(impl={"attention": "jnp"}):
            # inner scope derives from the outer one: both entries live
            assert policy.current().impl_for("matmul") == "pallas"
            assert policy.current().impl_for("attention") == "jnp"
        assert policy.current().impl_for("attention") == "auto"  # unwound
    assert policy.current().impl_for("matmul") == "auto"

    with pytest.raises(RuntimeError, match="boom"):
        with policy.apply(impl={"matmul": "jnp"}):
            raise RuntimeError("boom")
    assert policy.current().impl_for("matmul") == "auto"  # exception unwinds


def test_scopes_are_thread_isolated():
    seen = {}

    def worker():
        seen["impl"] = policy.current().impl_for("matmul")

    with policy.apply(impl={"matmul": "pallas"}):
        t = threading.Thread(target=worker)
        t.start()
        t.join()
        assert policy.current().impl_for("matmul") == "pallas"
    assert seen["impl"] == "auto"  # fresh thread: ambient, not our scope


def test_resolution_is_trace_time_under_jit():
    """Backend resolution happens while tracing (Python level), so a scope
    around the first call bakes the decision into the compiled function;
    later calls replay it without retracing — per-call positions and other
    traced values never consult the policy again."""
    resolved = []

    @jax.jit
    def f(x):
        resolved.append(registry.resolve("matmul", differentiable=False))
        return x + 1

    with policy.apply(impl={"*": "pallas"}):
        f(jnp.ones((2,)))
    assert resolved == ["pallas"]
    f(jnp.ones((2,)))  # outside the scope: no retrace, baked decision
    assert resolved == ["pallas"]


def test_install_sits_under_scopes():
    try:
        policy.install(policy.ambient().with_(impl={"*": "jnp"}))
        assert policy.current().impl_for("scan") == "jnp"
        with policy.apply(impl={"scan": "pallas"}):
            assert policy.current().impl_for("scan") == "pallas"
        assert policy.current().impl_for("scan") == "jnp"
    finally:
        policy.install(None)
    assert policy.current().impl_for("scan") == "auto"


# -- environment assembly -----------------------------------------------------

def test_ambient_env_assembly(monkeypatch):
    monkeypatch.setenv("REPRO_IMPL", "attention=jnp, *=pallas")
    monkeypatch.setenv("REPRO_STRICT_TILES", "1")
    monkeypatch.setenv("REPRO_INTERPRET", "1")
    amb = policy.ambient()
    assert amb.impl_for("attention") == "jnp"
    assert amb.impl_for("matmul") == "pallas"  # wildcard from env
    assert amb.strict_tiles is True
    assert amb.interpret is True
    monkeypatch.delenv("REPRO_IMPL")
    monkeypatch.delenv("REPRO_STRICT_TILES")
    monkeypatch.delenv("REPRO_INTERPRET")
    amb = policy.ambient()  # env-keyed memo re-assembles
    assert amb.impl_for("attention") == "auto" and amb.strict_tiles is False


def test_impl_grammar():
    assert policy.parse_impl_arg("*=pallas") == {"*": "pallas"}
    assert policy.parse_impl_arg("pallas") == {"*": "pallas"}  # bare backend
    assert policy.parse_impl_arg("attention=jnp,matmul=pallas") == {
        "attention": "jnp", "matmul": "pallas"}
    assert policy.parse_impl_arg("") == {}
    with pytest.raises(ValueError, match="unknown backend"):
        policy.parse_impl_arg("matmul=fancy")
    with pytest.raises(ValueError, match="empty op"):
        policy.parse_impl_arg("=pallas")
    with pytest.raises(ValueError, match="unknown op"):
        policy.parse_impl_arg("attnetion=pallas")  # typo'd op must not no-op


def test_impl_grammar_variant_knobs():
    """The variants extension: ``op=backend:knob=value`` entries carry typed
    per-op knobs alongside the impl map."""
    impl, variants = policy.parse_impl_spec("attention=pallas:kv_dtype=int8")
    assert impl == {"attention": "pallas"}
    assert variants == {"attention": {"kv_dtype": "int8"}}
    impl, variants = policy.parse_impl_spec(
        "matmul=pallas:backend=classical:qkv_fused=true,attention=jnp")
    assert impl == {"matmul": "pallas", "attention": "jnp"}
    assert variants == {"matmul": {"backend": "classical",
                                   "qkv_fused": True}}  # typed: bool
    _, variants = policy.parse_impl_spec("scan=pallas:block=128")
    assert variants == {"scan": {"block": 128}}  # typed: int
    # back-compat: the impl-only parser accepts knobs and drops them
    assert policy.parse_impl_arg("attention=pallas:kv_dtype=int8") == {
        "attention": "pallas"}
    with pytest.raises(ValueError, match="wildcard"):
        policy.parse_impl_spec("*=pallas:kv_dtype=int8")
    with pytest.raises(ValueError, match="knob=value"):
        policy.parse_impl_spec("attention=pallas:kv_dtype")


def test_per_op_interpret_variant(monkeypatch):
    """``--impl 'op=pallas:interpret=true'`` forces interpret mode for ONE
    op through the typed-knob grammar: the knob sits between the explicit
    call arg (stronger) and the policy-global ``interpret`` flag (weaker),
    and never leaks into the kernel's tile kwargs."""
    from repro.kernels.registry import KernelSpec
    seen = []

    def fake_pallas(x, *, interpret, **tiles):
        seen.append((interpret, "interpret" in tiles))
        return x

    monkeypatch.setitem(
        registry._REGISTRY, "scan",
        KernelSpec(name="scan", pallas=fake_pallas, ref=lambda x: x,
                   plan=lambda x: {}, supported=lambda: True))
    _, variants = policy.parse_impl_spec("scan=pallas:interpret=true")
    assert variants == {"scan": {"interpret": True}}  # typed bool

    x = jnp.ones((4,))
    with policy.apply(impl={"scan": "pallas"},
                      variants={"scan": {"interpret": True}}):
        registry.dispatch("scan", x)                       # knob forces on
        registry.dispatch("scan", x, interpret=False)      # explicit wins
    with policy.apply(impl={"scan": "pallas"},
                      variants={"scan": {"interpret": False}},
                      interpret=True):
        registry.dispatch("scan", x)            # knob beats the global flag
    with policy.apply(impl={"scan": "pallas"}):
        registry.dispatch("scan", x)            # no knob: native -> compiled
    assert seen == [(True, False), (False, False), (False, False),
                    (False, False)]


def test_describe_round_trips_variants():
    """describe()'s impl/variant prefix parses back to the same dispatch
    decisions (knob order and bool casing normalize)."""
    spec = "attention=pallas:kv_dtype=int8,matmul=pallas:qkv_fused=true"
    impl, variants = policy.parse_impl_spec(spec)
    pol = policy.ExecutionPolicy(impl=impl, variants=variants)
    rendered = pol.describe()
    impl2, variants2 = policy.parse_impl_spec(rendered)
    assert impl2 == dict(impl)
    assert variants2 == {op: dict(k) for op, k in variants.items()}


def test_ambient_env_carries_variants(monkeypatch):
    monkeypatch.setenv("REPRO_IMPL", "attention=pallas:kv_dtype=int8")
    amb = policy.ambient()
    assert amb.impl_for("attention") == "pallas"
    assert amb.variant_for("attention") == {"kv_dtype": "int8"}
    monkeypatch.delenv("REPRO_IMPL")
    assert policy.ambient().variant_for("attention") == {}


# -- resolver capability gates ------------------------------------------------

def test_resolve_capability_gates():
    with policy.apply(impl={"*": "pallas"}):
        # attention: custom softmax scale / traced window fail the needs gate
        assert registry.resolve("attention") == "pallas"
        assert registry.resolve("attention", softmax_scale=0.3) == "jnp"
        assert registry.resolve("attention",
                                window=jnp.asarray(4)) == "jnp"
        assert registry.resolve("attention", window=128) == "pallas"
        # ops without a registered backward stay jnp for model callers
        assert registry.resolve("scan") == "jnp"
        assert registry.resolve("scan", differentiable=False) == "pallas"
    # explicit jnp/ref force wins over everything
    with policy.apply(impl={"attention": "ref"}):
        assert registry.resolve("attention") == "jnp"


def test_policy_variant_overrides_reach_dispatch():
    x = jax.random.normal(jax.random.key(0), (2, 256))
    with policy.apply(variants={"scan": {"block": 60}}):
        # the policy's variant override reaches the kernel (non-divisor
        # block trips bp_scan's divisibility assert — proof it arrived)
        with pytest.raises(AssertionError):
            registry.dispatch("scan", x, impl="pallas")
        # an explicit call-site kwarg still wins over the policy variant
        out = registry.dispatch("scan", x, impl="pallas", block=64)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(registry.dispatch("scan", x, impl="ref")),
                               rtol=1e-4, atol=1e-4)


def test_policy_autotune_scope(monkeypatch):
    monkeypatch.delenv("REPRO_AUTOTUNE", raising=False)
    assert autotune.mode() == "off"
    with policy.apply(autotune="search"):
        assert autotune.mode() == "search"
        with policy.apply(impl={"matmul": "jnp"}):  # inherits from outer scope
            assert autotune.mode() == "search"
    assert autotune.mode() == "off"


def test_strict_tiles_policy(monkeypatch):
    monkeypatch.delenv("REPRO_STRICT_TILES", raising=False)
    x = jax.random.normal(jax.random.key(0), (2, 256))
    with policy.apply(strict_tiles=True):
        with pytest.raises(ValueError, match="ignored on the"):
            registry.dispatch("scan", x, impl="ref", block=64)
    with warnings.catch_warnings():
        warnings.simplefilter("always")
        registry.dispatch("scan", x, impl="ref", block=64)  # back to warning


def test_reset_warnings_rearms_warn_once():
    x = jax.random.normal(jax.random.key(0), (2, 256))
    with pytest.warns(UserWarning, match="ignored on the"):
        registry.dispatch("scan", x, impl="ref", block=64)
    with warnings.catch_warnings():  # second call: silent (warn-once)
        warnings.simplefilter("error")
        registry.dispatch("scan", x, impl="ref", block=64)
    registry.reset_warnings()
    with pytest.warns(UserWarning, match="ignored on the"):
        registry.dispatch("scan", x, impl="ref", block=64)


# -- RunOptions compat shim parity (acceptance bar) ---------------------------

FORCED = {"attention": "pallas", "matmul": "pallas"}


def _models(arch):
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    shim = build_model(cfg, RunOptions(remat="none", attention_impl="pallas",
                                       matmul_impl="pallas"))
    plain = build_model(cfg, RunOptions(remat="none"))
    return cfg, shim, plain


def _greedy(model, params, prompt, scope, steps=3, max_len=16):
    with scope:
        logits, cache = jax.jit(
            lambda p, t: model.prefill(p, t, max_len))(params, {"tokens": prompt})
        dec = jax.jit(model.decode_step)
        cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out = []
        for i in range(steps):
            out.append(np.asarray(cur[:, 0]))
            logits, cache = dec(params, cur, jnp.int32(prompt.shape[1] + i), cache)
            cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    return np.stack(out)


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "recurrentgemma-2b"])
def test_shim_matches_policy_greedy_decode(arch):
    """The deprecated RunOptions knobs and the equivalent ExecutionPolicy
    scope produce identical greedy-decode tokens (dense + hybrid)."""
    cfg, shim, plain = _models(arch)
    params = shim.init(jax.random.key(0))
    prompt = jax.random.randint(jax.random.key(1), (2, 6), 3, cfg.vocab_size)
    a = _greedy(shim, params, prompt, contextlib.nullcontext())
    b = _greedy(plain, params, prompt, policy.apply(impl=FORCED))
    np.testing.assert_array_equal(a, b)
    # and the forced route really differs from the all-jnp route upstream
    # decisions-wise: resolve flips under the scope
    with policy.apply(impl=FORCED):
        assert registry.resolve("matmul") == "pallas"
    assert registry.resolve("matmul") == "jnp"


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "recurrentgemma-2b"])
def test_shim_matches_policy_train_step(arch):
    """Loss and grads of one train step are identical between the shim and
    the equivalent policy scope."""
    cfg, shim, plain = _models(arch)
    params = shim.init(jax.random.key(0))
    batch = {
        "tokens": jax.random.randint(jax.random.key(2), (2, 16), 3, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.key(3), (2, 16), 0, cfg.vocab_size),
    }
    la, ga = jax.value_and_grad(shim.loss)(params, batch)
    with policy.apply(impl=FORCED):
        lb, gb = jax.value_and_grad(plain.loss)(params, batch)
    np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    for a, b in zip(jax.tree.leaves(ga), jax.tree.leaves(gb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_hybrid_ring_buffer_kernel_route_keeps_decode_exact():
    """The ring-buffer decode cache no longer pins itself to jnp: under a
    forced-pallas policy the RingKV layout maps its wrapped rows onto the
    flash kernel's per-row q_offset/kv_len vectors, and windowed decode
    with the rotated cache still matches the same model decoding over the
    full linear cache."""
    cfg = dataclasses.replace(get_smoke_config("recurrentgemma-2b"),
                              dtype="float32")
    ring = build_model(cfg, RunOptions(remat="none", windowed_decode_cache=True))
    full = build_model(cfg, RunOptions(remat="none"))
    params = ring.init(jax.random.key(0))
    prompt = jax.random.randint(jax.random.key(1), (1, 12), 3, cfg.vocab_size)
    scope = policy.apply(impl=FORCED)
    a = _greedy(ring, params, prompt, scope, steps=4, max_len=24)
    b = _greedy(full, params, prompt, policy.apply(impl=FORCED), steps=4,
                max_len=24)
    np.testing.assert_array_equal(a, b)


def test_expert_project_routes_through_registry():
    """MoE expert matmuls under a pallas policy: the registry matmul vmapped
    over the expert axis matches the batched einsum, forward and grads (the
    matmul custom VJP under vmap)."""
    from repro.models import common

    h = jax.random.normal(jax.random.key(0), (2, 4, 16, 32))  # (g, E, C, d)
    w = jax.random.normal(jax.random.key(1), (4, 32, 24))     # (E, d, f)
    want = common.expert_project(h, w)  # ambient on CPU: the jnp einsum
    gj = jax.grad(lambda a, b: common.expert_project(a, b).sum(),
                  argnums=(0, 1))(h, w)
    with policy.apply(impl={"matmul": "pallas"}):
        got = common.expert_project(h, w)
        gp = jax.grad(lambda a, b: common.expert_project(a, b).sum(),
                      argnums=(0, 1))(h, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)
    for a, b in zip(gp, gj):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-3)


# -- simulator namespace ------------------------------------------------------

def test_simulator_namespace_shares_op_names():
    """core/algorithms program builders are reachable under the kernel op
    names, so simulator cost cross-checks and KernelSpec lookups share one
    namespace."""
    from repro.core.hbp import BPProgram

    prog = registry.simulator_program("matmul", 8)
    assert isinstance(prog, BPProgram) and prog.name == "strassen"
    scan_progs = registry.simulator_program("scan", 16)
    assert [p.name for p in scan_progs] == ["msum", "psdist"]
    assert registry.simulator_program("transpose", 8).name == "mtbi"
    assert isinstance(registry.simulator_program("fft", 64), BPProgram)
    with pytest.raises(KeyError, match="no registered simulator"):
        registry.simulator_program("attention", 8)
    # one namespace: every simulator-bearing op is a registered kernel op
    sims = [n for n in registry.names() if registry.get(n).simulator]
    assert sims == ["fft", "matmul", "scan", "transpose"]
