"""Pallas kernel validation: shape/dtype sweeps vs the ref.py oracles,
resolved through the kernel registry (interpret mode — the kernel body
executes on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref, registry

DTYPES = [jnp.float32, jnp.bfloat16]


def tol(dtype):
    # bf16: two-pass scans/attention round intermediates to bf16; absolute
    # error grows with the running-sum magnitude
    return dict(rtol=3e-2, atol=8e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("rows,n,block", [(1, 256, 64), (4, 1024, 128), (3, 512, 512)])
def test_bp_scan_sweep(rows, n, block, dtype):
    x = jax.random.normal(jax.random.key(n), (rows, n), jnp.float32).astype(dtype)
    out = registry.dispatch("scan", x, impl="pallas", block=block)
    want = ref.bp_scan_ref(x)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(want, np.float32),
                               **tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("m,k,n,bm", [(128, 64, 128, 64), (256, 256, 256, 64),
                                      (64, 128, 64, 32)])
def test_hbp_matmul_sweep(m, k, n, bm, dtype):
    a = jax.random.normal(jax.random.key(m), (m, k), jnp.float32).astype(dtype)
    b = jax.random.normal(jax.random.key(n), (k, n), jnp.float32).astype(dtype)
    out = registry.dispatch("matmul", a, b, impl="pallas",
                            bm=bm, bn=bm, bk=min(bm, k), morton=False)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(want, np.float32),
                               rtol=5e-2 if dtype == jnp.bfloat16 else 1e-3,
                               atol=5e-1 if dtype == jnp.bfloat16 else 1e-3)


def test_hbp_matmul_morton_equals_rowmajor():
    a = jax.random.normal(jax.random.key(0), (256, 256), jnp.float32)
    b = jax.random.normal(jax.random.key(1), (256, 256), jnp.float32)
    o1 = registry.dispatch("matmul", a, b, impl="pallas",
                           bm=64, bn=64, bk=64, morton=True)
    o2 = registry.dispatch("matmul", a, b, impl="pallas",
                           bm=64, bn=64, bk=64, morton=False)
    np.testing.assert_allclose(o1, o2, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("m,n,bt,morton", [(128, 128, 64, True), (256, 128, 64, False),
                                           (64, 64, 64, True)])
def test_bi_transpose_sweep(m, n, bt, morton, dtype):
    x = jax.random.normal(jax.random.key(m * n), (m, n), jnp.float32).astype(dtype)
    out = registry.dispatch("transpose", x, impl="pallas", bt=bt, morton=morton)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x.T))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 40), (False, 0)])
@pytest.mark.parametrize("bh,s,hd", [(2, 256, 64), (4, 128, 128)])
def test_flash_attention_sweep(bh, s, hd, causal, window, dtype):
    q = jax.random.normal(jax.random.key(1), (bh, s, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(jax.random.key(2), (bh, s, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(jax.random.key(3), (bh, s, hd), jnp.float32).astype(dtype)
    out = registry.dispatch("attention", q, k, v, impl="pallas",
                            causal=causal, window=window, q_block=64, kv_block=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(want, np.float32),
                               **tol(dtype))


@pytest.mark.parametrize("rows,n", [(1, 256), (4, 1024)])
def test_fft_sweep(rows, n):
    xr = jax.random.normal(jax.random.key(n), (rows, n), jnp.float32)
    xi = jax.random.normal(jax.random.key(n + 1), (rows, n), jnp.float32)
    x = (xr + 1j * xi).astype(jnp.complex64)
    out = registry.dispatch("fft", x, impl="pallas")
    want = ref.fft_ref(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-3, atol=2e-3)
