"""Per-architecture smoke tests (reduced configs): one train step + prefill +
decode on CPU, asserting shapes, finiteness, and prefill/decode consistency
(the strongest cache-correctness check: logits from decode after prefill(t)
must match logits from prefill(t+1))."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config, list_archs
from repro.models import RunOptions, build_model

ARCHS = list_archs()


def fp32_cfg(arch):
    # ample MoE capacity: token-drop patterns legitimately differ between
    # prefill-batch and decode-batch dispatch (and across microbatch splits);
    # consistency tests need the drop-free regime
    return dataclasses.replace(get_smoke_config(arch), dtype="float32",
                               capacity_factor=8.0)


def make_batch(model, b, s, rng):
    cfg = model.cfg
    toks = jax.random.randint(rng, (b, s), 3, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    for k, spec in model.batch_extras_specs(b, s).items():
        batch[k] = jax.random.normal(jax.random.key(7), spec.shape, jnp.float32).astype(spec.dtype)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_chunked_prefill_matches_whole_prefill(arch):
    """Streaming a prompt through prefill_chunk (the engine's path: first
    chunk runs the modality frontend / fresh attend, continuations attend
    the cache prefix) lands on the same last-token logits as one whole
    prefill — for EVERY family.  Attention families are fp-exact; hybrid's
    LRU h0-fold and ssm's SSD boundary reassociate in ulps, hence the
    consistency-test tolerance."""
    cfg = fp32_cfg(arch)
    model = build_model(cfg, RunOptions(remat="none"))
    params = model.init(jax.random.key(0))
    b, s, max_len, chunk = 2, 16, 32, 8
    batch = make_batch(model, b, s, jax.random.key(1))
    logits_full, _ = jax.jit(
        lambda p, bb: model.prefill(p, bb, max_len))(params, batch)

    cache = model.init_cache(b, max_len)
    extras = {k: batch[k] for k in model.batch_extras_specs(b, s)} or None
    step = jax.jit(model.prefill_chunk, static_argnames=("first",))
    for off in range(0, s, chunk):
        logits, cache = step(params, batch["tokens"][:, off:off + chunk],
                             jnp.int32(off), cache, first=(off == 0),
                             extras=extras)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits_full),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = fp32_cfg(arch)
    model = build_model(cfg, RunOptions(remat="none"))
    params = model.init(jax.random.key(0))
    batch = make_batch(model, 2, 16, jax.random.key(1))
    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert jnp.isfinite(loss), arch
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.all(jnp.isfinite(leaf))), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """decode_step(x_t | prefill(x_{<t})) == prefill(x_{<=t}) logits."""
    cfg = fp32_cfg(arch)
    model = build_model(cfg, RunOptions(remat="none"))
    params = model.init(jax.random.key(0))
    b, t, max_len = 2, 8, 16
    batch = make_batch(model, b, t + 1, jax.random.key(1))
    toks = batch["tokens"]

    short = dict(batch, tokens=toks[:, :t])
    logits_a, cache = jax.jit(lambda p, bb: model.prefill(p, bb, max_len))(params, short)
    logits_b, _ = jax.jit(model.decode_step)(params, toks[:, t : t + 1], jnp.int32(t), cache)

    full = dict(batch, tokens=toks[:, : t + 1])
    logits_full, _ = jax.jit(lambda p, bb: model.prefill(p, bb, max_len))(params, full)

    np.testing.assert_allclose(np.asarray(logits_b), np.asarray(logits_full),
                               rtol=2e-3, atol=2e-3)
    assert logits_a.shape == (b, cfg.vocab_size)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_microbatched_loss_matches(arch):
    """Gradient accumulation must not change the CE loss value.  (The MoE
    load-balance aux term is legitimately nonlinear in the batch split, so it
    is zeroed here.)"""
    from repro.launch.steps import make_train_step
    from repro.optim import adamw_init

    cfg = dataclasses.replace(fp32_cfg(arch), router_aux_weight=0.0)
    m1 = build_model(cfg, RunOptions(remat="none", microbatches=1))
    m2 = build_model(cfg, RunOptions(remat="none", microbatches=2))
    params = m1.init(jax.random.key(0))
    opt = adamw_init(params)
    batch = make_batch(m1, 4, 16, jax.random.key(1))
    _, _, met1 = jax.jit(make_train_step(m1))(params, opt, batch)
    _, _, met2 = jax.jit(make_train_step(m2))(params, adamw_init(params), batch)
    np.testing.assert_allclose(float(met1["loss"]), float(met2["loss"]), rtol=1e-4)


def test_gemma3_local_global_pattern():
    from repro.models.dense import GLOBAL_WINDOW, layer_windows

    cfg = get_smoke_config("gemma3-1b")  # global_every=3, 6 layers
    w = np.asarray(layer_windows(cfg))
    assert (w[[2, 5]] == GLOBAL_WINDOW).all()
    assert (w[[0, 1, 3, 4]] == cfg.sliding_window).all()


def test_banded_local_attention_matches_masked():
    """Beyond-paper optimization must be numerically exact."""
    from repro.models import common

    b, s, h, hd, w = 2, 512, 2, 32, 64
    q = jax.random.normal(jax.random.key(1), (b, s, h, hd))
    k = jax.random.normal(jax.random.key(2), (b, s, h, hd))
    v = jax.random.normal(jax.random.key(3), (b, s, h, hd))
    pos = jnp.arange(s, dtype=jnp.int32)
    o1 = common.attention_banded_local(q, k, v, pos, pos, window=w)
    o2 = common.attention_dense(q, k, v, pos, pos, causal=True, window=w)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=2e-4, atol=2e-4)


@pytest.mark.slow
def test_hybrid_windowed_decode_cache_matches_full():
    """Ring-buffer cache decode == full cache decode for recurrentgemma."""
    cfg = dataclasses.replace(get_smoke_config("recurrentgemma-2b"), dtype="float32")
    m_full = build_model(cfg, RunOptions(remat="none"))
    m_ring = build_model(cfg, RunOptions(remat="none", windowed_decode_cache=True))
    params = m_full.init(jax.random.key(0))
    b, t = 2, 12
    toks = jax.random.randint(jax.random.key(1), (b, t + 4), 3, cfg.vocab_size)
    batch = {"tokens": toks[:, :t]}
    max_len = 32
    lg_f, c_f = m_full.prefill(params, batch, max_len)
    lg_r, c_r = m_ring.prefill(params, batch, max_len)
    np.testing.assert_allclose(np.asarray(lg_f), np.asarray(lg_r), rtol=1e-4, atol=1e-4)
    for i in range(3):
        nxt = toks[:, t + i : t + i + 1]
        lg_f, c_f = m_full.decode_step(params, nxt, jnp.int32(t + i), c_f)
        lg_r, c_r = m_ring.decode_step(params, nxt, jnp.int32(t + i), c_r)
        np.testing.assert_allclose(np.asarray(lg_f), np.asarray(lg_r), rtol=1e-3, atol=1e-3)
