"""HBP IR structural validators + measured f(r)/L(r) vs Table 1."""
import pytest

from repro.core.algorithms import (
    BItoRMDirect,
    BItoRMGapped,
    MSum,
    MTBI,
    RMtoBI,
    prefix_sums_programs,
)
from repro.core.hbp import (
    Memory,
    check_balance,
    check_limited_access,
    measure_block_sharing,
    measure_cache_friendliness,
)


@pytest.mark.parametrize("mk", [
    lambda mem: MSum(256, mem),
    lambda mem: MTBI(16, mem),
    lambda mem: RMtoBI(16, mem),
    lambda mem: BItoRMDirect(16, mem),
])
def test_balance_and_limited_access(mk):
    prog = mk(Memory(16))
    assert check_balance(prog)
    assert check_limited_access(prog)


def test_msum_is_cache_friendly_f1():
    """Scans: f(r) = O(1) (Table 1)."""
    prog = MSum(1024, Memory(16))
    f = measure_cache_friendliness(prog, block=16)
    for r, excess in f.items():
        if r >= 16:
            assert excess <= 8, (r, excess)  # O(1) blocks beyond r/B


def test_mtbi_block_sharing_L1():
    """MT in BI layout: L(r) = O(1)."""
    prog = MTBI(32, Memory(16))
    L = measure_block_sharing(prog, block=16)
    for r, shared in L.items():
        if r >= 64:
            assert shared <= 4, (r, shared)


def test_bi_to_rm_direct_has_sqrt_block_sharing():
    """Direct BI->RM: L(r) = Theta(sqrt r) — concurrent tasks share RM row
    blocks.  This is the failure mode the gapping technique removes."""
    prog = BItoRMDirect(32, Memory(16))
    L = measure_block_sharing(prog, block=16)
    mids = {r: s for r, s in L.items() if 64 <= r <= 512}
    assert any(s >= (r ** 0.5) / 4 for r, s in mids.items()), mids


def test_gapping_removes_block_sharing_for_large_tasks():
    direct = measure_block_sharing(BItoRMDirect(32, Memory(16)), block=16)
    gapped = measure_block_sharing(BItoRMGapped(32, Memory(16)), block=16)
    # compare at the largest common task size with >= 2 tasks
    big = max(r for r in direct if r in gapped and r >= 256)
    assert gapped[big] <= direct[big], (gapped[big], direct[big])


def test_prefix_sums_is_type1_sequence():
    progs = prefix_sums_programs(256, Memory(16))
    assert len(progs) == 2
    for p in progs:
        assert check_balance(p)
        assert check_limited_access(p)
