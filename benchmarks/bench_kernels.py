"""Kernel microbenchmarks, resolved through the kernel registry.

For every registered op this times:

  * ``ref``            — the jnp oracle path (``dispatch(..., impl="ref")``,
                         the per-call policy override)
                         — the XLA numbers that matter on this CPU container;
  * ``pallas_fixed``   — the Pallas path (interpret mode on CPU) with the
                         pre-substrate hard-coded tiles (128 / 512 / 256);
  * ``pallas_planned`` — the Pallas path with planner-derived tiles;
  * ``pallas_tuned``   — the planned path overlaid by the persisted autotune
                         table (``benchmarks/autotune.py`` populates it;
                         falls back to the analytic plan on a cold cache).

The ``matmul_strassen`` case additionally records ``pallas_classical_us``
(planner tiles, backend forced classical) next to ``pallas_planned_us``
(which routes the planner's Strassen choice at that shape), so the
crossover claim — Strassen beats classical above the modeled edge — is
measured, not asserted.  The ``mlp`` case times the model-level
``gated_mlp`` under a jnp vs a pallas execution-policy scope (the registry
route model traffic takes).

Interpret-mode wall times are NOT meaningful device performance; they are
recorded so the before/after planner tiling delta is machine-checkable.  On
the TPU target the same dispatch compiles natively.  Emits
``name,us_per_call,derived`` CSV rows and (via ``main(json_path=...)``) a
machine-readable ``BENCH_kernels.json``.

``--ops`` filters cases by name or registry op (e.g. ``--ops matmul`` runs
the matmul + matmul_strassen arms only — the CI smoke arm); a filtered run
skips the JSON write unless ``--json`` is given explicitly, and when it
does write it merges its arms into the existing file's ``ops`` instead of
clobbering the others.  The ``serve_faulted`` arm measures the engine's
fault-recovery overhead (clean vs seeded-fault-plan run, tokens asserted
identical).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.kernels import autotune, planner, registry  # noqa: E402

# the hard-coded tile constants the substrate replaced, kept here as the
# benchmark's "before" arm
LEGACY_TILES = {
    "scan": {"block": 512},
    "matmul": {"bm": 128, "bn": 128, "bk": 128},
    # pre-substrate "before": fixed tiles AND no Strassen schedule
    "matmul_strassen": {"bm": 128, "bn": 128, "bk": 128,
                        "backend": "classical"},
    "transpose": {"bt": 128},
    "attention": {"q_block": 256, "kv_block": 256},
    "attention_decode": {"q_block": 256, "kv_block": 256},
    "fft": {"n1": 1},  # pre-substrate: no four-step split (one dense DFT)
}


def timeit(fn, *args, iters=5):
    """The autotune harness's discipline (median-of-k per-call, compile
    excluded), shared so the arms and the search winners are comparable."""
    return autotune.measure_us(fn, args, iters=iters)


def _cases():
    """Arm name -> case.  ``op`` is the registry op the arm dispatches (the
    decode arm reuses ``attention`` with a query offset over a KV cache)."""
    key = jax.random.key
    x = jax.random.normal(key(0), (8, 8192), jnp.float32)
    a = jax.random.normal(key(1), (512, 512), jnp.float32)
    b = jax.random.normal(key(2), (512, 512), jnp.float32)
    q = jax.random.normal(key(3), (8, 512, 64), jnp.float32)
    k = jax.random.normal(key(4), (8, 512, 64), jnp.float32)
    v = jax.random.normal(key(5), (8, 512, 64), jnp.float32)
    # decode regime: one query row per head over a mostly-full 1024-slot
    # cache (static kv_len -> the kernel's planner-aware grid shrink)
    qd = jax.random.normal(key(8), (8, 1, 64), jnp.float32)
    kc = jax.random.normal(key(9), (8, 1024, 64), jnp.float32)
    vc = jax.random.normal(key(10), (8, 1024, 64), jnp.float32)
    kv_len = 1000
    xc = (jax.random.normal(key(6), (4, 1024))
          + 1j * jax.random.normal(key(7), (4, 1024))).astype(jnp.complex64)
    # the largest benched square shape: above the modeled crossover, so the
    # planner routes the Strassen backend; the classical extra arm measures
    # the same shape with the backend forced back
    a2 = jax.random.normal(key(11), (1024, 1024), jnp.float32)
    b2 = jax.random.normal(key(12), (1024, 1024), jnp.float32)
    return {
        "scan": dict(op="scan", args=(x,), kwargs={}, label="8x8192",
                     derived=lambda us: f"{x.size * 4 * 2 / (us / 1e6) / 1e9:.2f}GB/s"),
        "matmul": dict(op="matmul", args=(a, b), kwargs={}, label="512",
                       derived=lambda us: f"{2 * 512**3 / (us / 1e6) / 1e9:.1f}GFLOP/s"),
        "matmul_strassen": dict(
            op="matmul", args=(a2, b2), kwargs={}, label="1024",
            extra_arms={"pallas_classical": {"backend": "classical"}},
            derived=lambda us: f"{2 * 1024**3 / (us / 1e6) / 1e9:.1f}GFLOP/s"),
        "transpose": dict(op="transpose", args=(a,), kwargs={}, label="512",
                          derived=lambda us: f"{a.size * 4 * 2 / (us / 1e6) / 1e9:.2f}GB/s"),
        "attention": dict(op="attention", args=(q, k, v),
                          kwargs={"causal": False, "window": 0},
                          label="8x512x64",
                          derived=lambda us: f"{4 * 8 * 512 * 512 * 64 / (us / 1e6) / 1e9:.1f}GFLOP/s"),
        "attention_decode": dict(op="attention", args=(qd, kc, vc),
                                 kwargs={"causal": True, "window": 0,
                                         "q_offset": kv_len - 1,
                                         "kv_len": kv_len},
                                 label="8x1q_1024kv",
                                 derived=lambda us: f"{4 * 8 * kv_len * 64 / (us / 1e6) / 1e9:.2f}GFLOP/s"),
        "fft": dict(op="fft", args=(xc,), kwargs={}, label="4x1024",
                    derived=lambda us: f"{5 * 4 * 1024 * 10 / (us / 1e6) / 1e9:.2f}GFLOP/s"),
    }


def _bench_mlp() -> dict:
    """Model-level arm: ``gated_mlp`` under a jnp vs a pallas execution
    policy scope — what serve/train traffic sees once model matmuls dispatch
    through the substrate."""
    from repro.kernels import policy
    from repro.models import common as model_common

    key = jax.random.key
    x = jax.random.normal(key(20), (512, 256), jnp.float32)
    wg = jax.random.normal(key(21), (256, 1024), jnp.float32) * 0.05
    wu = jax.random.normal(key(22), (256, 1024), jnp.float32) * 0.05
    wd = jax.random.normal(key(23), (1024, 256), jnp.float32) * 0.05
    flops = 3 * 2 * 512 * 256 * 1024
    entry: dict = {"op": "mlp", "shape": "512x256x1024"}
    with autotune.mode_scope("off"):
        for arm, backend in (("jnp", "jnp"), ("pallas_planned", "pallas")):
            with policy.apply(impl={"matmul": backend}):
                fn = jax.jit(lambda *a: model_common.gated_mlp(*a))
                us = timeit(fn, x, wg, wu, wd)
            entry[f"{arm}_us"] = round(us, 1)
            print(f"kernel_mlp_{arm}_512x256x1024,{us:.0f},"
                  f"{flops / (us / 1e6) / 1e9:.1f}GFLOP/s")
    return entry


def _bench_serve_decode() -> dict:
    """Serving arm: one GQA decode attention step (b=4, h=8, kvh=1 — the
    deepest grouping — over a 4096-slot cache), timed as the model layer runs
    it.  ``native`` is the kernel-native GQA route (K/V at their native head
    count, the kv index map sharing blocks across the group); ``prerepeat``
    reconstructs the pre-PR adapter (materialize ``repeat_kv`` to the full
    query head count, then dispatch) — the cache-sized copy the fast path
    deletes, re-paid every decode step.  Reported as tokens/sec (b tokens
    per step through this one attention layer) so the serving claim is
    machine-checkable; interpret-mode absolute numbers are still not device
    performance."""
    from repro.kernels import policy
    from repro.models import common as model_common

    key = jax.random.key
    b, h, kvh, hd, sk = 4, 8, 1, 64, 4096
    q = jax.random.normal(key(30), (b, 1, h, hd), jnp.float32)
    k = jax.random.normal(key(31), (b, sk, kvh, hd), jnp.float32)
    v = jax.random.normal(key(32), (b, sk, kvh, hd), jnp.float32)
    q_pos = jnp.full((1,), sk - 1, jnp.int32)
    k_pos = jnp.arange(sk, dtype=jnp.int32)
    entry: dict = {"op": "attention", "shape": f"{b}x1q_{sk}kv_gqa{h // kvh}"}

    def native(q, k, v):
        return model_common.attention(q, k, v, q_pos, k_pos, causal=True)

    def prerepeat(q, k, v):
        # the old adapter: repeat the cache to h heads, then fold + dispatch
        kr = model_common.repeat_kv(k, h // kvh)
        vr = model_common.repeat_kv(v, h // kvh)

        def fold(x):
            return x.transpose(0, 2, 1, 3).reshape(b * h, x.shape[1], hd)

        out = registry.dispatch(
            "attention", fold(q), fold(kr), fold(vr), causal=True,
            q_offset=sk - 1, kv_len=sk, impl="pallas")
        return out.reshape(b, h, 1, hd).transpose(0, 2, 1, 3)

    with autotune.mode_scope("off"):
        for arm, fn in (("native", native), ("prerepeat", prerepeat)):
            with policy.apply(impl={"attention": "pallas"}):
                us = timeit(jax.jit(fn), q, k, v)
            entry[f"{arm}_us"] = round(us, 1)
            entry[f"{arm}_tok_per_s"] = round(b / (us / 1e6), 1)
            print(f"kernel_serve_decode_{arm}_{entry['shape']},{us:.0f},"
                  f"{b / (us / 1e6):.1f}tok/s")
    return entry


def _bench_serve_continuous() -> dict:
    """Serving-loop arm: the continuous-batching engine vs the lockstep
    wave baseline, end to end (prefill + greedy decode) on a skewed
    workload — one straggler (``max_new=24``) rides with three short
    requests (``max_new=2``) per wave of 4 slots, three waves.  Lockstep
    pays the straggler's steps for every row of its wave; the engine evicts
    the short rows and backfills from the queue, so the same model serves
    the same tokens in far fewer batched decode launches.  Both systems run
    the same jitted model functions on the same params (seed 0) and are
    warmed up (compile excluded) before timing; prompts fit one prefill
    chunk.  Reported as tokens/sec; interpret-mode absolute numbers are
    still not device performance — the launch-count ratio is the claim."""
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.launch.engine import Engine
    from repro.launch.mesh import make_debug_mesh
    from repro.launch.serve import Request, Server
    from repro.models.base import RunOptions

    cfg = get_smoke_config("qwen3-1.7b")
    mesh = make_debug_mesh(tp=min(2, len(jax.devices())))
    slots, waves = 4, 3
    rng = np.random.default_rng(0)
    spec = []  # (prompt, max_new): one straggler per lockstep wave.  Equal
    # prompt lengths so the lockstep wave needs no left-padding — batched
    # lockstep, run-alone, and the engine then all emit identical tokens
    for _ in range(waves):
        for mn in (24, 2, 2, 2):
            spec.append((rng.integers(3, cfg.vocab_size, 12).astype(np.int32),
                         mn))

    def requests():
        return [Request(i, p, max_new=mn) for i, (p, mn) in enumerate(spec)]

    server = Server(cfg, mesh, max_batch=slots, max_len=64)
    engine = Engine(cfg, mesh, max_batch=slots, max_len=64, chunk=16,
                    opts=RunOptions())
    # warmup: compile both systems' jitted paths outside the timed region
    server.run_batch([Request(0, spec[0][0], max_new=2)])
    engine.run([Request(0, spec[0][0], max_new=2)])

    reqs = requests()
    lock_s = 0.0
    for w in range(waves):  # lockstep serves in waves of the slot count
        lock_s += server.run_batch(reqs[w * slots:(w + 1) * slots])["wall_s"]
    lock_toks = sum(len(r.out) for r in reqs)

    creqs = requests()
    cont = engine.run(creqs)
    assert [r.out for r in creqs] == [r.out for r in reqs], \
        "continuous tokens diverge from lockstep tokens"

    entry = {
        "op": "serve", "shape": f"{slots}slots_{len(spec)}reqs_skewed",
        "lockstep_tok_per_s": round(lock_toks / max(lock_s, 1e-9), 1),
        "continuous_tok_per_s": round(cont["tok_per_s"], 1),
        "speedup": round((cont["tok_per_s"] * max(lock_s, 1e-9)) / lock_toks, 2),
        "continuous_decode_steps": cont["decode_steps"],
        "continuous_prefill_chunks": cont["prefill_chunks"],
        "telemetry": cont["telemetry"],
    }
    print(f"kernel_serve_lockstep_{entry['shape']},"
          f"{lock_s / max(lock_toks, 1) * 1e6:.0f},"
          f"{entry['lockstep_tok_per_s']}tok/s")
    print(f"kernel_serve_continuous_{entry['shape']},"
          f"{cont['wall_s'] / max(cont['tokens'], 1) * 1e6:.0f},"
          f"{entry['continuous_tok_per_s']}tok/s "
          f"({entry['speedup']}x lockstep)")
    return entry


def _bench_serve_continuous_hybrid() -> dict:
    """The non-dense serving claim: the same continuous-batching engine
    loop serves the HYBRID family (LRU/conv recurrent state in a
    ``StateCarry`` layout, parked rows riding identity updates) on the same
    skewed straggler workload as the dense arm.  ``chunk`` covers the
    prompts so prefill is single-chunk (the LRU h0-fold reassociates across
    chunk boundaries); tokens are asserted identical to lockstep."""
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.launch.engine import Engine
    from repro.launch.mesh import make_debug_mesh
    from repro.launch.serve import Request, Server
    from repro.models.base import RunOptions

    cfg = get_smoke_config("recurrentgemma-2b")
    mesh = make_debug_mesh(tp=min(2, len(jax.devices())))
    slots, waves = 4, 2
    rng = np.random.default_rng(0)
    spec = []
    for _ in range(waves):
        for mn in (24, 2, 2, 2):
            spec.append((rng.integers(3, cfg.vocab_size, 12).astype(np.int32),
                         mn))

    def requests():
        return [Request(i, p, max_new=mn) for i, (p, mn) in enumerate(spec)]

    server = Server(cfg, mesh, max_batch=slots, max_len=64)
    engine = Engine(cfg, mesh, max_batch=slots, max_len=64, chunk=16,
                    opts=RunOptions())
    server.run_batch([Request(0, spec[0][0], max_new=2)])
    engine.run([Request(0, spec[0][0], max_new=2)])

    reqs = requests()
    lock_s = 0.0
    for w in range(waves):
        lock_s += server.run_batch(reqs[w * slots:(w + 1) * slots])["wall_s"]
    lock_toks = sum(len(r.out) for r in reqs)

    creqs = requests()
    cont = engine.run(creqs)
    assert [r.out for r in creqs] == [r.out for r in reqs], \
        "hybrid continuous tokens diverge from lockstep tokens"

    entry = {
        "op": "serve", "shape": f"hybrid_{slots}slots_{len(spec)}reqs_skewed",
        "lockstep_tok_per_s": round(lock_toks / max(lock_s, 1e-9), 1),
        "continuous_tok_per_s": round(cont["tok_per_s"], 1),
        "speedup": round((cont["tok_per_s"] * max(lock_s, 1e-9)) / lock_toks, 2),
        "continuous_decode_steps": cont["decode_steps"],
        "continuous_prefill_chunks": cont["prefill_chunks"],
        "telemetry": cont["telemetry"],
    }
    print(f"kernel_serve_lockstep_{entry['shape']},"
          f"{lock_s / max(lock_toks, 1) * 1e6:.0f},"
          f"{entry['lockstep_tok_per_s']}tok/s")
    print(f"kernel_serve_continuous_{entry['shape']},"
          f"{cont['wall_s'] / max(cont['tokens'], 1) * 1e6:.0f},"
          f"{entry['continuous_tok_per_s']}tok/s "
          f"({entry['speedup']}x lockstep)")
    return entry


def _bench_serve_faulted() -> dict:
    """Recovery-overhead arm: the SAME workload through the engine clean,
    then under a seeded fault plan firing all three fault kinds — a decode
    raise (bounded retry), a prefill straggler delay, and a poisoned slot
    (non-finite row -> bisect, evict, resume from its last snapshot).  The
    fresh injector is installed AFTER warmup so the warmup run cannot burn
    the plan's entries.  Tokens are asserted request-for-request identical
    and ``snapshot_restores >= 1`` — the measured claim is that recovery
    costs bounded wall time, never correctness."""
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.launch.engine import Engine
    from repro.launch.mesh import make_debug_mesh
    from repro.launch.serve import Request
    from repro.models.base import RunOptions
    from repro.runtime.fault_tolerance import FaultInjector

    plan = "decode@1=raise,prefill@1=delay:0.05,slot@1=nan_logits:3"
    cfg = get_smoke_config("qwen3-1.7b")
    mesh = make_debug_mesh(tp=min(2, len(jax.devices())))
    rng = np.random.default_rng(0)
    spec = [(rng.integers(3, cfg.vocab_size, 12).astype(np.int32), mn)
            for mn in (8, 6, 8, 4, 6, 8)]

    def requests():
        return [Request(i, p, max_new=mn) for i, (p, mn) in enumerate(spec)]

    engine = Engine(cfg, mesh, max_batch=3, max_len=64, chunk=8,
                    snapshot_every=2, injector=FaultInjector(""),
                    opts=RunOptions())
    # warmup = the full workload once, so compiles AND the snapshot path's
    # one-time eager lowering land outside both timed runs
    engine.run(requests())

    clean_reqs = requests()
    clean = engine.run(clean_reqs)

    engine.injector = FaultInjector(plan)
    faulted_reqs = requests()
    faulted = engine.run(faulted_reqs)

    tel = faulted["telemetry"]
    assert [r.out for r in faulted_reqs] == [r.out for r in clean_reqs], \
        "faulted tokens diverge from the clean run"
    assert tel["retries"] >= 1, "the decode raise never retried"
    assert tel["slots_poisoned"] == 1, "the poisoned slot was not bisected"
    assert tel["snapshot_restores"] >= 1, "recovery skipped the snapshot"

    entry = {
        "op": "serve", "shape": "faulted_3slots_6reqs", "plan": plan,
        "clean_tok_per_s": round(clean["tok_per_s"], 1),
        "faulted_tok_per_s": round(faulted["tok_per_s"], 1),
        "recovery_overhead": round(
            faulted["wall_s"] / max(clean["wall_s"], 1e-9), 2),
        "faulted_decode_steps": faulted["decode_steps"],
        "clean_decode_steps": clean["decode_steps"],
        "telemetry": tel,
    }
    print(f"kernel_serve_clean_{entry['shape']},"
          f"{clean['wall_s'] / max(clean['tokens'], 1) * 1e6:.0f},"
          f"{entry['clean_tok_per_s']}tok/s")
    print(f"kernel_serve_faulted_{entry['shape']},"
          f"{faulted['wall_s'] / max(faulted['tokens'], 1) * 1e6:.0f},"
          f"{entry['faulted_tok_per_s']}tok/s "
          f"({entry['recovery_overhead']}x clean, tokens identical)")
    return entry


def _bench_serve_router() -> dict:
    """Fleet-scaling arm: the SAME skewed 12-request workload through a
    1-replica and a 2-replica ``Router`` (pws arm, max_batch=2 per
    replica).  The recorded ratio is fleet throughput against the MAKESPAN
    clock ``max(busy_s)`` — on this one-device rig replicas time-share the
    device, so per-replica busy time is the production-shape number (see
    "Fleet clock" in the router docstring); the sequential wall is recorded
    alongside for honesty.  Warmup = the full workload once per fleet;
    best-of-3 timed runs; tokens asserted identical across fleet sizes.  A
    faulted variant then kills one replica mid-decode and records the
    recovery overhead of salvage + checkpoint-streamed respawn + snapshot
    migration over the clean 2-replica makespan — with tokens again
    identical and ``replica_restarts >= 1``."""
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.launch.mesh import make_debug_mesh
    from repro.launch.router import Router
    from repro.launch.serve import Request
    from repro.models.base import RunOptions
    from repro.runtime.fault_tolerance import FaultInjector

    cfg = get_smoke_config("qwen3-1.7b")
    mesh = make_debug_mesh(tp=1)
    rng = np.random.default_rng(0)
    # skewed: one long generation per wave of shorts, three waves
    spec = [(rng.integers(3, cfg.vocab_size, 12).astype(np.int32), mn)
            for _ in range(3) for mn in (24, 2, 2, 2)]

    def requests():
        return [Request(i, p, max_new=mn) for i, (p, mn) in enumerate(spec)]

    # degrade_after pinned high: timing jitter on the shared device must not
    # trip the watchdog into shrinking a fleet's active slots mid-trial
    kw = dict(max_batch=2, max_len=64, chunk=16, snapshot_every=8,
              degrade_after=10**9, opts=RunOptions())

    def fleet(n):
        router = Router(cfg, mesh, n_replicas=n, route="pws", **kw)
        router.run(requests())          # warmup: compiles land untimed
        best, toks = None, None
        for _ in range(3):
            reqs = requests()
            out = router.run(reqs)
            if best is None or out["fleet_busy_s"] < best["fleet_busy_s"]:
                best = out
            if toks is None:
                toks = [r.out for r in reqs]
            else:
                assert [r.out for r in reqs] == toks, \
                    "router tokens vary across timed trials"
        return router, best, toks

    _, one, toks1 = fleet(1)
    router2, two, toks2 = fleet(2)
    assert toks1 == toks2, "fleet size changed the tokens"
    speedup = two["fleet_tok_per_s"] / max(one["fleet_tok_per_s"], 1e-9)
    assert speedup >= 1.6, \
        f"2-replica fleet speedup {speedup:.2f}x under the 1.6x bar"

    # faulted variant on its own fleet: a snapshot cadence dense enough
    # that rows killed at decode ordinal 4 carry host snapshots to migrate;
    # the killing plan installs AFTER warmup + its own clean baseline run
    del router2
    frouter = Router(cfg, mesh, n_replicas=2, route="pws",
                     **dict(kw, snapshot_every=2))
    frouter.run(requests())             # warmup
    clean2_reqs = requests()
    clean2 = frouter.run(clean2_reqs)
    frouter.replicas[1].engine.injector = FaultInjector("decode@4=raise:99")
    faulted_reqs = requests()
    faulted = frouter.run(faulted_reqs)
    fc = faulted["counters"]
    assert [r.out for r in faulted_reqs] == [r.out for r in clean2_reqs], \
        "faulted-fleet tokens diverge from the clean run"
    assert fc["replica_restarts"] >= 1 and fc["migrations"] >= 1

    entry = {
        "op": "serve", "shape": "router_12reqs_skewed", "route": "pws",
        "replicas": 2, "slots_per_replica": 2,
        "fleet_tok_per_s_1rep": round(one["fleet_tok_per_s"], 1),
        "fleet_tok_per_s_2rep": round(two["fleet_tok_per_s"], 1),
        "fleet_speedup_2rep": round(speedup, 2),
        "seq_tok_per_s_2rep": round(two["tok_per_s"], 1),
        "faulted": {
            "plan": "|decode@4=raise:99",
            "fleet_tok_per_s": round(faulted["fleet_tok_per_s"], 1),
            "recovery_overhead": round(
                faulted["fleet_busy_s"] / max(clean2["fleet_busy_s"], 1e-9),
                2),
            "replica_deaths": fc["replica_deaths"],
            "replica_restarts": fc["replica_restarts"],
            "requeued_on_death": fc["requeued_on_death"],
            "migrations": fc["migrations"],
        },
    }
    print(f"kernel_serve_router_1rep_{entry['shape']},"
          f"{one['fleet_busy_s'] / max(one['tokens'], 1) * 1e6:.0f},"
          f"{entry['fleet_tok_per_s_1rep']}tok/s")
    print(f"kernel_serve_router_2rep_{entry['shape']},"
          f"{two['fleet_busy_s'] / max(two['tokens'], 1) * 1e6:.0f},"
          f"{entry['fleet_tok_per_s_2rep']}tok/s "
          f"({entry['fleet_speedup_2rep']}x fleet, tokens identical)")
    print(f"kernel_serve_router_faulted_{entry['shape']},"
          f"{faulted['fleet_busy_s'] / max(faulted['tokens'], 1) * 1e6:.0f},"
          f"{entry['faulted']['recovery_overhead']}x clean fleet "
          f"({fc['replica_restarts']} respawn(s), tokens identical)")
    return entry


def main(json_path: str | None = None, ops: list[str] | None = None) -> dict:
    results: dict[str, dict] = {}
    cases = _cases()
    if ops:
        cases = {n: c for n, c in cases.items() if n in ops or c["op"] in ops}
    for name, case in cases.items():
        op, args, kwargs = case["op"], case["args"], case["kwargs"]
        plan = dict(registry.get(op).plan(*args))
        entry: dict = {"op": op, "shape": case["label"], "planned_tiles": plan}

        ref_fn = jax.jit(lambda *a, _n=op, _kw=kwargs: registry.dispatch(
            _n, *a, impl="ref", **_kw))
        us = timeit(ref_fn, *args)
        entry["ref_us"] = round(us, 1)
        print(f"kernel_{name}_ref_{case['label']},{us:.0f},{case['derived'](us)}")

        # fixed/planned arms pin the mode off: an inherited REPRO_AUTOTUNE +
        # warm table must not overlay tuned tiles onto the comparison baseline
        arms = [("pallas_fixed", LEGACY_TILES[name]), ("pallas_planned", {})]
        arms += list(case.get("extra_arms", {}).items())
        with autotune.mode_scope("off"):
            for arm, tiles in arms:
                fn = jax.jit(lambda *a, _n=op, _kw=kwargs, _t=tiles: registry.dispatch(
                    _n, *a, impl="pallas", **_kw, **_t))
                us = timeit(fn, *args, iters=5)
                entry[f"{arm}_us"] = round(us, 1)
                print(f"kernel_{name}_{arm}_{case['label']},{us:.0f},interpret")

        # tuned arm: same dispatch, persisted measurements replayed on top of
        # the plan (identical to pallas_planned when the table has no entry);
        # the lookup keys the semantic kwargs (masking regime / decode flag)
        # and mirrors replay's cross-shape interpolation fallback, so the
        # recorded tiles are the ones the timed dispatch actually ran
        tuned = autotune.lookup(op, *args, kwargs=kwargs)
        if tuned is None:
            tuned = autotune.nearest_plan(op, *args, kwargs=kwargs)
        entry["tuned_tiles"] = autotune.snap_plan(op, args, tuned) if tuned else plan
        with autotune.mode_scope("replay"):
            fn = jax.jit(lambda *a, _n=op, _kw=kwargs: registry.dispatch(
                _n, *a, impl="pallas", **_kw))
            us = timeit(fn, *args, iters=5)
        entry["pallas_tuned_us"] = round(us, 1)
        print(f"kernel_{name}_pallas_tuned_{case['label']},{us:.0f},interpret")
        results[name] = entry

    if ops is None or "mlp" in ops:
        results["mlp"] = _bench_mlp()
    if ops is None or "serve_decode" in ops:
        results["serve_decode"] = _bench_serve_decode()
    if ops is None or "serve_continuous" in ops:
        results["serve_continuous"] = _bench_serve_continuous()
    if ops is None or "serve_continuous_hybrid" in ops:
        results["serve_continuous_hybrid"] = _bench_serve_continuous_hybrid()
    if ops is None or "serve_faulted" in ops:
        results["serve_faulted"] = _bench_serve_faulted()
    if ops is None or "serve_router" in ops:
        results["serve_router"] = _bench_serve_router()

    from repro.kernels import policy
    dp = planner.device_params()
    prov = autotune.provenance()
    payload = {
        "device": {"platform": dp.platform, "kind": dp.kind,
                   "fast_bytes": dp.fast_bytes, "line_bytes": dp.line_bytes},
        # provenance: the ambient execution policy and autotune table the
        # numbers were measured under
        "policy": policy.current().describe(),
        "autotune": prov,
        "ops": results,
    }
    if json_path:
        out = Path(json_path)
        if ops and out.exists():
            # a filtered run UPDATES its arms in the existing file instead
            # of clobbering the others (device/policy provenance refreshes)
            prior = json.loads(out.read_text()).get("ops", {})
            payload["ops"] = {**prior, **results}
        out.write_text(json.dumps(payload, indent=2) + "\n")
        print(f"# wrote {json_path}")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--ops", default="",
                    help="comma-separated case/op filter (e.g. 'matmul' runs "
                         "the matmul + matmul_strassen smoke arms)")
    ap.add_argument("--json", default=None,
                    help="output path (default: BENCH_kernels.json for a "
                         "full run; filtered runs print only)")
    cli = ap.parse_args()
    wanted = [o for o in cli.ops.split(",") if o] or None
    path = cli.json or (None if wanted else str(REPO / "BENCH_kernels.json"))
    main(json_path=path, ops=wanted)
