"""Kernel microbenchmarks: wall time of the jnp reference path (the
interpret-mode Pallas numbers are NOT meaningful performance on CPU; on the
TPU target ops.py dispatches to pallas_call).  Emits name,us_per_call,derived
rows; 'derived' = GFLOP/s or GB/s of the reference path."""
from __future__ import annotations

import sys
import time
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.kernels import ref  # noqa: E402


def timeit(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def main() -> None:
    x = jax.random.normal(jax.random.key(0), (8, 8192), jnp.float32)
    f = jax.jit(ref.bp_scan_ref)
    us = timeit(f, x)
    gbs = x.size * 4 * 2 / (us / 1e6) / 1e9
    print(f"kernel_bp_scan_ref_8x8192,{us:.0f},{gbs:.2f}GB/s")

    a = jax.random.normal(jax.random.key(1), (512, 512), jnp.float32)
    b = jax.random.normal(jax.random.key(2), (512, 512), jnp.float32)
    f = jax.jit(ref.matmul_ref)
    us = timeit(f, a, b)
    gf = 2 * 512**3 / (us / 1e6) / 1e9
    print(f"kernel_matmul_ref_512,{us:.0f},{gf:.1f}GFLOP/s")

    f = jax.jit(ref.transpose_ref)
    us = timeit(f, a)
    print(f"kernel_transpose_ref_512,{us:.0f},{a.size * 4 * 2 / (us / 1e6) / 1e9:.2f}GB/s")

    q = jax.random.normal(jax.random.key(3), (8, 512, 64), jnp.float32)
    k = jax.random.normal(jax.random.key(4), (8, 512, 64), jnp.float32)
    v = jax.random.normal(jax.random.key(5), (8, 512, 64), jnp.float32)
    f = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
    us = timeit(f, q, k, v)
    gf = 4 * 8 * 512 * 512 * 64 / (us / 1e6) / 1e9
    print(f"kernel_attention_ref_8x512x64,{us:.0f},{gf:.1f}GFLOP/s")


if __name__ == "__main__":
    main()
