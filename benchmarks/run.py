"""Benchmark harness entry point — one section per paper table/figure.
Prints ``name,us_per_call,derived`` CSV.

Sections:
  * table1.*      — the paper's Table 1 structural parameters + bounds,
                    measured on the simulated multicore under PWS
  * pws_vs_rws.*  — the paper's scheduler comparison (block misses, steals)
  * kernel.*      — Pallas kernel reference-path microbenches
  * roofline      — run ``python -m benchmarks.roofline`` for the dry-run
                    derived roofline table (separate: needs dry-run records)
"""
from __future__ import annotations

import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))


def main() -> None:
    from benchmarks import bench_kernels, table1

    print("name,us_per_call,derived")
    table1.main()
    # also emits the machine-readable per-op report (before/after planner
    # tiling) next to the repo root
    bench_kernels.main(json_path=str(REPO / "BENCH_kernels.json"))


if __name__ == "__main__":
    main()
