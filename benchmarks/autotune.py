"""Populate persisted autotune tile tables for every registered op.

For each op the sweep covers the benchmark shapes (the ones
``bench_kernels.py`` reports) plus smaller neighbours, so serving/training
shapes that bucket into the same power-of-two classes replay measured tiles.
Each (op, shape) runs ``repro.kernels.autotune.search``: a power-of-two
candidate ladder around the planner's analytic point, timed compile-excluded
median-of-k, winner persisted under ``REPRO_TUNE_DIR`` keyed by
``(device_kind, op, shape_class, dtype)``.

Usage:
  PYTHONPATH=src python benchmarks/autotune.py              # all ops
  PYTHONPATH=src python benchmarks/autotune.py --ops scan,fft --iters 7
  REPRO_TUNE_DIR=/tmp/tune python benchmarks/autotune.py    # alternate table

Then regenerate ``BENCH_kernels.json`` (``python benchmarks/bench_kernels.py``)
to record the ``pallas_tuned_us`` column next to the fixed/planned arms.
"""
from __future__ import annotations

import argparse
import os
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.kernels import autotune, planner, registry  # noqa: E402


def _sweep() -> dict[str, list[tuple]]:
    """Per-op (args, kwargs) sweep.  The first case of each op is the
    bench_kernels.py shape, so the tuned arm there hits the table."""
    key = jax.random.key

    def n(k, shape, dtype=jnp.float32):
        return jax.random.normal(key(k), shape, dtype)

    def c(k, shape):
        return (jax.random.normal(key(k), shape)
                + 1j * jax.random.normal(key(k + 100), shape)).astype(jnp.complex64)

    return {
        "scan": [((n(0, (8, 8192)),), {}),
                 ((n(1, (8, 4096)),), {})],
        "matmul": [((n(2, (512, 512)), n(3, (512, 512))), {}),
                   ((n(4, (256, 256)), n(5, (256, 256))), {}),
                   # above the modeled Strassen crossover: the search covers
                   # backend/cutoff/morton variants alongside the tile ladder
                   # (bench_kernels' matmul_strassen shape)
                   ((n(19, (1024, 1024)), n(20, (1024, 1024))), {})],
        "transpose": [((n(6, (512, 512)),), {}),
                      ((n(7, (256, 256)),), {})],
        "attention": [((n(8, (8, 512, 64)), n(9, (8, 512, 64)),
                        n(10, (8, 512, 64))), {"causal": False, "window": 0}),
                      ((n(11, (4, 256, 64)), n(12, (4, 256, 64)),
                        n(13, (4, 256, 64))), {"causal": True, "window": 0}),
                      # decode regime (bench_kernels' attention_decode arm):
                      # one query row over a mostly-full cache
                      ((n(16, (8, 1, 64)), n(17, (8, 1024, 64)),
                        n(18, (8, 1024, 64))),
                       {"causal": True, "window": 0,
                        "q_offset": 999, "kv_len": 1000})],
        "fft": [((c(14, (4, 1024)),), {}),
                ((c(15, (4, 512)),), {})],
    }


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--ops", default="",
                    help="comma-separated subset (default: all registered)")
    ap.add_argument("--iters", type=int, default=5,
                    help="timing repeats per candidate (median taken)")
    ap.add_argument("--max-candidates", type=int, default=16)
    ap.add_argument("--dir", default=None,
                    help="table directory (else REPRO_TUNE_DIR / default)")
    args = ap.parse_args(argv)

    if args.dir:
        os.environ["REPRO_TUNE_DIR"] = args.dir
        autotune.clear_cache()

    wanted = [o for o in args.ops.split(",") if o] or registry.names()
    sweep = _sweep()
    dp = planner.device_params()
    print(f"# autotune search on {dp.kind} ({dp.platform}), "
          f"fast_bytes={dp.fast_bytes}, table dir {autotune.tune_dir()}")

    entries = {}
    for op in wanted:
        if op not in sweep:
            print(f"# skipping {op!r}: no tuning metadata")
            continue
        for case_args, case_kwargs in sweep[op]:
            entry = autotune.search(op, *case_args, iters=args.iters,
                                    max_candidates=args.max_candidates,
                                    **case_kwargs)
            label = autotune.shape_class(*case_args)
            # analytic_us is None when the analytic candidate itself failed
            # to run (possible on native backends; search skips, not aborts)
            base = entry["analytic_us"] if entry["analytic_us"] is not None \
                else entry["us"]
            gain = base / max(entry["us"], 1e-9)
            print(f"autotune_{op}_{label},{entry['us']:.0f},"
                  f"analytic={base:.0f}us,x{gain:.2f},{entry['plan']}")
            entries[f"{op}|{label}"] = entry
    path = autotune.save_table(dp.kind)
    print(f"# wrote {len(autotune.load_table(dp.kind))} entries to {path}")
    return entries


if __name__ == "__main__":
    main()
