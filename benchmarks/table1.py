"""Reproduce the paper's Table 1: per-algorithm structural parameters
measured on the simulator — work W(n) (access count), sequential cache
complexity Q(n, M, B), PWS cache/block-miss excess, steals — plus asymptotic
slope checks (log-log fits across an n-sweep).

Each function emits ``name,us_per_call,derived`` CSV rows (us_per_call is
simulator wall time; 'derived' carries the headline measured quantity).
"""
from __future__ import annotations

import math
import sys
import time
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))

from repro.core import costmodel  # noqa: E402
from repro.core.algorithms import (  # noqa: E402
    BItoRMDirect,
    MSum,
    MTBI,
    RMtoBI,
    bi_to_rm_gapped_programs,
    prefix_sums_programs,
    strassen_program,
)
from repro.core.hbp import Memory  # noqa: E402
from repro.core.machine import Machine  # noqa: E402
from repro.core.pws import PWS  # noqa: E402
from repro.core.rws import RWS  # noqa: E402

P, M, B = 8, 512, 16


def run(make, p=P, sched=None):
    m = Machine(p, M, B, scheduler=sched or PWS())
    progs = make()
    t0 = time.time()
    st = m.run_sequence(progs) if isinstance(progs, list) else m.run(progs)
    return st, (time.time() - t0) * 1e6


def slope(xs, ys):
    lx = [math.log2(x) for x in xs]
    ly = [math.log2(max(y, 1)) for y in ys]
    n = len(xs)
    mx, my = sum(lx) / n, sum(ly) / n
    num = sum((a - mx) * (b - my) for a, b in zip(lx, ly))
    den = sum((a - mx) ** 2 for a in lx)
    return num / den


def bench_scan_row():
    """Scans: W=O(n), Q=O(n/B) — slopes ~1; PWS cache excess <= c pM/B."""
    ns = [1 << 10, 1 << 12, 1 << 14]
    W, Q = [], []
    for n in ns:
        st, _ = run(lambda n=n: MSum(n, Memory(B)), p=1)
        W.append(st.accesses)
        Q.append(st.total_cache_misses())
    st_p, us = run(lambda: MSum(ns[-1], Memory(B)))
    st_1, _ = run(lambda: MSum(ns[-1], Memory(B)), p=1)
    excess = st_p.total_cache_misses() - st_1.total_cache_misses()
    print(f"table1_scan_W_slope,{us:.0f},{slope(ns, W):.2f}")
    print(f"table1_scan_Q_slope,{us:.0f},{slope(ns, Q):.2f}")
    print(f"table1_scan_pws_excess_vs_pMB,{us:.0f},"
          f"{excess / costmodel.pws_cache_excess_bp(P, M, B):.3f}")


def bench_mt_row():
    ns = [16, 32, 64]
    W = []
    for n in ns:
        st, _ = run(lambda n=n: MTBI(n, Memory(B)), p=1)
        W.append(st.accesses)
    st_p, us = run(lambda: MTBI(64, Memory(B)))
    print(f"table1_mt_W_slope_vs_n2,{us:.0f},{slope([n * n for n in ns], W):.2f}")
    print(f"table1_mt_block_misses,{us:.0f},{st_p.total_block_misses()}")


def bench_gapping_row():
    """The gapping technique: block misses direct vs gapped (PWS)."""
    st_d, us1 = run(lambda: BItoRMDirect(64, Memory(B)))
    st_g, us2 = run(lambda: bi_to_rm_gapped_programs(64, Memory(B)))
    print(f"table1_bi2rm_direct_block_misses,{us1:.0f},{st_d.total_block_misses()}")
    print(f"table1_bi2rm_gapped_block_misses,{us2:.0f},{st_g.total_block_misses()}")


def bench_pws_vs_rws():
    """The paper's headline comparison on a block-sharing computation."""
    st_p, us = run(lambda: BItoRMDirect(64, Memory(B)), sched=PWS())
    rws_bm = []
    rws_steals = []
    for s in range(5):
        st_r, _ = run(lambda: BItoRMDirect(64, Memory(B)), sched=RWS(seed=s))
        rws_bm.append(st_r.total_block_misses())
        rws_steals.append(len(st_r.steals))
    print(f"pws_block_misses,{us:.0f},{st_p.total_block_misses()}")
    print(f"rws_block_misses_mean,{us:.0f},{sum(rws_bm) / len(rws_bm):.1f}")
    print(f"pws_steals,{us:.0f},{len(st_p.steals)}")
    print(f"rws_steals_mean,{us:.0f},{sum(rws_steals) / len(rws_steals):.1f}")


def bench_strassen_row():
    ns = [8, 16, 32]
    W = []
    for n in ns:
        st, _ = run(lambda n=n: strassen_program(n, Memory(B), base=4), p=1)
        W.append(st.accesses)
    st_p, us = run(lambda: strassen_program(16, Memory(B), base=4))
    lam = slope(ns, W)
    print(f"table1_strassen_W_slope,{us:.0f},{lam:.2f}")  # ~log2(7)=2.81
    print(f"table1_strassen_steals,{us:.0f},{len(st_p.steals)}")


def bench_prefix_sums_row():
    st_p, us = run(lambda: prefix_sums_programs(1 << 13, Memory(B)))
    spp = st_p.steals_per_priority()
    print(f"table1_ps_max_steals_per_priority,{us:.0f},{max(spp.values()) if spp else 0}")


def main() -> None:
    bench_scan_row()
    bench_mt_row()
    bench_gapping_row()
    bench_pws_vs_rws()
    bench_strassen_row()
    bench_prefix_sums_row()


if __name__ == "__main__":
    main()
