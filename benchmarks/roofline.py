"""Roofline analysis (§Roofline deliverable): read the dry-run records and
derive, per (arch x shape x mesh):

  compute term    = HLO_FLOPs / peak_FLOPs            [s]
  memory term     = HLO_bytes / HBM_bw                [s]
  collective term = wire_bytes / ICI_bw               [s]

(all per-device quantities — the HLO module is the per-device program), the
dominant bottleneck, MODEL_FLOPS = 6*N_active*D (train) or 2*N_active*D
(prefill/decode), the usefulness ratio MODEL_FLOPS / HLO_FLOPs, and a
one-line remedy for the dominant term.

Hardware model (TPU v5e-like): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link
ICI, 16 GiB HBM.

Caveats recorded in EXPERIMENTS.md: (1) the CPU backend upcasts bf16 dot
operands to f32, inflating activation collective payloads ~2x vs the TPU
target; (2) HLO_bytes is a static traffic bound (every materializing op
counted at operand+result bytes); (3) decode-cell matvecs may lower to
fused multiply-reduce instead of dot, undercounting decode compute terms —
decode cells are memory-bound regardless.
"""
from __future__ import annotations

import glob
import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))

from repro.configs import SHAPES, get_config  # noqa: E402

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
HBM_BYTES = 16 * 2**30

REMEDY = {
    "compute_s": "increase arithmetic intensity (larger tiles, fused qkv/mlp)",
    "memory_s": "cut HBM traffic: fuse layout ops, shrink remat recompute, "
                "bf16-ize fp32 intermediates, windowed KV for local layers",
    "collective_s": "reduce wire bytes: RS instead of AR, bf16 collectives, "
                    "overlap weight all-gathers with compute, gradient compression",
}


def model_flops(arch: str, shape_name: str, n_devices: int) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    # exclude the lookup-only embedding table (logits matmul params stay)
    emb = cfg.vocab_size * cfg.d_model
    n_matmul = max(n_active - emb, 1)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n_matmul * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n_matmul * tokens
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        total = 2.0 * n_matmul * tokens
    return total / n_devices  # per-device share


def load_cells(tag: str = "") -> list[dict]:
    cells = []
    for f in sorted(glob.glob(str(REPO / "experiments" / "dryrun" / "*.json"))):
        r = json.loads(Path(f).read_text())
        if r.get("tag", "") != tag:
            continue
        cells.append(r)
    return cells


def analyze_cell(r: dict) -> dict | None:
    if not r["status"].startswith("ok"):
        return None
    h = r["hlo"]
    comp = h["flops"] / PEAK_FLOPS
    mem = h["hbm_bytes"] / HBM_BW
    coll = h["collective_bytes"] / ICI_BW
    dom = max([("compute_s", comp), ("memory_s", mem), ("collective_s", coll)],
              key=lambda kv: kv[1])[0]
    mf = model_flops(r["arch"], r["shape"], r["n_devices"])
    useful = mf / h["flops"] if h["flops"] > 0 else float("nan")
    bound = max(comp, mem, coll)
    frac = comp / bound if bound > 0 else 0.0  # fraction of roofline (compute/limiter)
    return {
        **{k: r[k] for k in ("arch", "shape", "mesh")},
        "compute_s": comp, "memory_s": mem, "collective_s": coll,
        "dominant": dom, "model_flops": mf, "useful_ratio": useful,
        "roofline_frac": frac,
        "peak_gb": r["memory"]["peak_bytes_est"] / 1e9,
        "fits": r["memory"]["peak_bytes_est"] <= HBM_BYTES,
        "remedy": REMEDY[dom],
    }


def main() -> None:
    rows = []
    skips = []
    for r in load_cells():
        out = analyze_cell(r)
        if out is None:
            skips.append((r["arch"], r["shape"], r["mesh"], r["status"]))
        else:
            rows.append(out)
    rows.sort(key=lambda x: (x["arch"], x["shape"], x["mesh"]))

    hdr = (f"{'arch':24s} {'shape':12s} {'mesh':6s} {'comp_s':>8s} {'mem_s':>8s} "
           f"{'coll_s':>8s} {'dom':5s} {'useful':>7s} {'rl_frac':>7s} {'peakGB':>7s} fits")
    print(hdr)
    print("-" * len(hdr))
    for x in rows:
        print(f"{x['arch']:24s} {x['shape']:12s} {x['mesh']:6s} "
              f"{x['compute_s']:8.3f} {x['memory_s']:8.3f} {x['collective_s']:8.3f} "
              f"{x['dominant'][:4]:5s} {x['useful_ratio']:7.3f} "
              f"{x['roofline_frac']:7.3f} {x['peak_gb']:7.2f} "
              f"{'Y' if x['fits'] else 'N'}")
    for s in skips:
        print(f"{s[0]:24s} {s[1]:12s} {s[2]:6s} {s[3]}")

    out_file = REPO / "experiments" / "roofline.json"
    out_file.write_text(json.dumps(rows, indent=1))
    print(f"\nwrote {out_file} ({len(rows)} cells, {len(skips)} skips)")


if __name__ == "__main__":
    main()
